"""Quickstart: cut the long tail of a k-means run (paper §4 in ~40 lines).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.data import load

# 1. data → random-sampled groups (paper §5.2)
data = load("skin", n=30_000, seed=0)
groups = core.random_groups(data, group_size=6_000, max_groups=5)
k = 2

# 2. training: run a few groups to convergence, record (accuracy, change-rate)
traces = []
for i in range(3):
    x = jnp.asarray(groups[i])
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(i), x, k)
    res = core.kmeans_fit_traced(x, c0, max_iters=200)
    r, h = core.trace_to_rh(res, k)
    traces.append((np.asarray(r), np.asarray(h)))

# 3. fit the paper's quadratic regression  h = β₀ + β₁r + β₂r²  (Eq. 8)
model = core.fit_longtail(traces, algorithm="kmeans", dataset="skin",
                          family="quadratic")
print("regression:", [round(c, 4) for c in model.regression.coeffs],
      f"R²={model.regression.metrics.r2:.4f}")

# 4. pick a desired accuracy → stopping threshold h* = f(r*)
h_star = model.threshold_for(0.99)
print(f"h*(99%) = {h_star:.3e}")

# 5. production: early-stopped run (on-device while_loop) vs full run
x = jnp.asarray(groups[4])
c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(99), x, k)
_, labels_es, _, iters_es = core.kmeans_fit_earlystop(x, c0, h_star,
                                                      max_iters=400)
_, labels_full, _, iters_full = core.kmeans_fit_full(x, c0, max_iters=400)

acc = float(core.rand_index(labels_es, labels_full, k, k))
rep = core.report(time_actual_s=float(iters_es),
                  time_full_s=float(iters_full))   # iterations ∝ time ∝ cost
print(f"early stop after {int(iters_es)}/{int(iters_full)} iterations "
      f"→ achieved accuracy {acc:.4f}")
print(f"cost-effectiveness (Eq. 10): {rep.cost_effectiveness:.2f} "
      f"→ {100 * (1 - rep.cost_effectiveness):.0f}% of the bill cut")

# 6. the same run through the unified engine, at scale: stream the sweep
#    over 8 chunks (no [N,K] intermediate) and race 4 restarts as one
#    vmapped program — the threshold rides in via the fitted model.
cfg = core.EngineConfig.from_longtail(model, 0.99, max_iters=400,
                                      chunks=8, stop_when_frozen=True)
eng = core.ClusteringEngine("kmeans", cfg)
rr = eng.fit_restarts(x, key=jax.random.PRNGKey(99), k=k, restarts=4)
acc_best = float(core.rand_index(rr.best.labels, labels_full, k, k))
print(f"engine (8 chunks, 4 restarts): best J={float(rr.best.objective):.1f} "
      f"from restart {int(rr.best_index)} after "
      f"{int(rr.best.n_iters)} iters → accuracy {acc_best:.4f} "
      f"(per-restart iters {list(map(int, rr.n_iters))})")
