"""The paper's motivating example (§2.1, §5.4): land-use classification of
satellite imagery with early-stopped clustering + the cloud cost model.

Trains the regression once on sample images (image = group, §5.2), then
early-stops every production image at 99% desired accuracy and scales the
measured savings to California / US land area on EC2 m5.large pricing.

    PYTHONPATH=src python examples/landuse_spacenet.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import landuse_case_study
from repro.core.cost_model import CALIFORNIA_AREA_KM2, US_AREA_KM2
from repro.data import spacenet_pixels

K = 6                       # forest/water/road/building/grassland/wasteland
RES = (96, 96, 3)           # reduced from 438×406 for the demo; scaled below
DESIRED = 0.99

print("generating synthetic SpaceNet-like imagery…")
train_imgs = spacenet_pixels(n_images=4, k_true=K, seed=0, shape=RES)
prod_imgs = spacenet_pixels(n_images=3, k_true=K, seed=1, shape=RES)

# --- training: once, amortised over every later use (Eq. 9) ---
t0 = time.time()
traces = []
for i, img in enumerate(train_imgs):
    x = jnp.asarray(img)
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(i), x, K)
    res = core.kmeans_fit_traced(x, c0, max_iters=200)
    r, h = core.trace_to_rh(res, K)
    traces.append((np.asarray(r), np.asarray(h)))
model = core.fit_longtail(traces, algorithm="kmeans", dataset="spacenet",
                          family="quadratic")
h_star = model.threshold_for(DESIRED)
t_train = time.time() - t0
print(f"trained on {len(train_imgs)} images in {t_train:.1f}s; "
      f"h*({DESIRED:.0%}) = {h_star:.3e}")

# --- production: early-stop each image; measure vs full convergence ---
t_full = 0.0
iters_es = iters_full = 0
accs = []
for i, img in enumerate(prod_imgs):
    x = jnp.asarray(img)
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(100 + i), x, K)
    _, labels, _, it_es = core.kmeans_fit_earlystop(x, c0, h_star,
                                                    max_iters=400)
    jax.block_until_ready(labels)
    t0 = time.time()
    _, labels_f, _, it_full = core.kmeans_fit_full(x, c0, max_iters=400)
    jax.block_until_ready(labels_f)
    t_full += time.time() - t0
    iters_es += int(it_es)
    iters_full += int(it_full)
    accs.append(float(core.rand_index(labels, labels_f, K, K)))
    print(f"  image {i}: {int(it_es)}/{int(it_full)} iters, "
          f"accuracy {accs[-1]:.4f}")

# cost ∝ iterations at fixed (n, k) — the paper's §3.3 proxy; wall time at
# this reduced demo resolution is dominated by dispatch overhead
frac = iters_es / iters_full
print(f"\nmean achieved accuracy {np.mean(accs):.4f} "
      f"(desired {DESIRED:.0%}); cost-effectiveness {frac:.2f} "
      f"({iters_es}/{iters_full} iterations)")

# --- scale to the case study (per-image time scaled to full resolution) ---
scale = (438 * 406) / (RES[0] * RES[1])
t_image_full = (t_full / len(prod_imgs)) * scale
for area, label in ((CALIFORNIA_AREA_KM2, "California"),
                    (US_AREA_KM2, "United States")):
    rep = landuse_case_study(t_image_full, frac, area_km2=area,
                             time_train_s=t_train)
    print(f"{label:14s}: full-run cost ${rep.cost_full_usd:,.2f} → "
          f"saves ${rep.savings_usd:,.2f} per use "
          f"(training cost ${rep.cost_train_usd:.4f}, amortised)")
