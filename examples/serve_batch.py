"""Batched serving example: continuous batching over a mixed request queue.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_lm, count_params
from repro.serving import Server, Request

cfg = get_config("mistral-nemo-12b", reduced=True)
params = init_lm(jax.random.PRNGKey(0), cfg)
print(f"serving {cfg.name} ({count_params(cfg)/1e6:.2f}M params reduced)")

srv = Server(params, cfg, n_slots=4, max_seq=128)
rng = np.random.default_rng(0)
requests = [
    Request(prompt=list(rng.integers(1, cfg.vocab, size=int(n))),
            max_new_tokens=int(m), temperature=t, rid=i)
    for i, (n, m, t) in enumerate([(5, 12, 0.0), (9, 8, 0.0), (3, 16, 0.8),
                                   (7, 10, 0.0), (4, 6, 0.5), (11, 9, 0.0)])
]
t0 = time.time()
out = srv.generate(requests)
dt = time.time() - t0
total = sum(len(v) for v in out.values())
print(f"{len(requests)} requests → {total} tokens in {dt:.2f}s "
      f"({total/dt:.1f} tok/s, {srv.n_slots} slots, continuous batching)")
for rid in sorted(out):
    print(f"  req {rid} ({len(out[rid])} tokens): {out[rid][:8]}…")
