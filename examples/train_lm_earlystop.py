"""End-to-end LM training driver with the long-tail controller (beyond-paper
generalisation, DESIGN.md §2): pilot run fits the h(r) regression on the
loss curve; the main run early-stops at a desired fraction of final quality.

Uses a ~20M-parameter dense transformer (the CPU-friendly stand-in for the
assignment's "~100M for a few hundred steps"; pass --big for ~100M).

    PYTHONPATH=src python examples/train_lm_earlystop.py --steps 150
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.base import ArchConfig
from repro.training import Trainer, TrainConfig, OptimizerConfig


def make_cfg(big: bool) -> ArchConfig:
    if big:   # ~100M — the assignment's e2e scale; several hours on 1 CPU core
        return ArchConfig(name="demo-100m", family="dense", n_layers=12,
                          d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
                          d_ff=2048, vocab=32_000, period=("attn",),
                          remat="none")
    # CPU-demo scale: converges visibly in ~150 steps (the long tail exists)
    return ArchConfig(name="demo-3m", family="dense", n_layers=6,
                      d_model=192, n_heads=6, n_kv_heads=3, head_dim=32,
                      d_ff=768, vocab=512, period=("attn",), remat="none")


def data(cfg, batch, seq, seed=0):
    """Ramp stream (next token = current + 1): quickly learnable, so the
    loss curve shows a clear long tail to cut."""
    rng = np.random.default_rng(seed)
    while True:
        start = rng.integers(0, cfg.vocab, size=(batch, 1))
        yield {"tokens": jnp.asarray((start + np.arange(seq)) % cfg.vocab,
                                     jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--desired-quality", type=float, default=0.95)
    args = ap.parse_args()

    cfg = make_cfg(args.big)
    from repro.models import count_params
    print(f"model: {cfg.name} ({count_params(cfg)/1e6:.1f}M params)")
    tc = TrainConfig(opt=OptimizerConfig(peak_lr=5e-3, warmup_steps=10,
                                         total_steps=args.steps))

    # --- pilot: run to the budget, harvest (quality r, change-rate h) ---
    print(f"pilot run ({args.steps} steps)…")
    pilot = Trainer(cfg, tc, data(cfg, args.batch, args.seq), seed=1)
    pilot.run(args.steps)
    losses = np.array([m["loss"] for m in pilot.metrics_log])
    first, final = losses[:3].mean(), losses[-5:].mean()
    ema = 0.95
    r, h = core.harvest_lm_trace(losses, ema=ema)   # same EMA as the hook
    model = core.fit_longtail([(r, h)], algorithm="lm_train",
                              dataset="ramp", family=None, balanced=True)
    print(f"pilot: loss {first:.3f} → {final:.3f}; regression "
          f"({model.regression.family}) R² = {model.regression.metrics.r2:.3f}")

    # --- main run: early-stop at the desired quality fraction ---
    hook = core.EarlyStopHook(model, desired_accuracy=args.desired_quality,
                              ema=ema, patience=5,
                              min_steps=max(20, args.steps // 5))
    print(f"main run with h* = {hook.h_star:.3e} "
          f"(desired quality {args.desired_quality:.0%})…")
    main_t = Trainer(cfg, tc, data(cfg, args.batch, args.seq),
                     earlystop=hook, seed=1)
    rep = main_t.run(args.steps)
    stopped_loss = main_t.metrics_log[-1]["loss"]
    progress = (first - stopped_loss) / max(first - final, 1e-9)
    print(f"stopped at step {rep['final_step']}/{args.steps} "
          f"(early={rep['stopped_early']}), loss {stopped_loss:.3f} "
          f"→ realised {progress:.0%} of the pilot's improvement "
          f"for {rep['final_step'] / args.steps:.0%} of the compute")


if __name__ == "__main__":
    main()
