"""Benchmark harness — one function per paper table/figure + kernel
microbenches + the roofline table (reads the dry-run JSONs).

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run --only fig7,table2

Output: CSV rows to stdout (name,metric,value,…) and benchmarks/out/*.csv.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

import numpy as np

from benchmarks.timing import time_callable

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
_REGISTRY = {}


def bench(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def _emit(name: str, rows: list[dict]):
    os.makedirs(OUT_DIR, exist_ok=True)
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = list(rows[0])
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
    print(f"\n== {name} ({path})")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


# --------------------------------------------------------------------------
# Fig. 2/3/5 — the long tail
# --------------------------------------------------------------------------

@bench("fig5_longtail")
def fig5_longtail():
    """Clustering accuracy over iterations: iterations to reach 95/99/100%
    of accuracy for both algorithms (the long-tail phenomenon)."""
    from benchmarks.paper_experiments import run_group, load_groups
    rows = []
    for algorithm in ("kmeans", "em"):
        groups, k = load_groups("3D_Road/4")
        g = run_group(groups[0], k, algorithm, seed=1)
        r = g.accuracies
        def first_at(th):
            idx = np.where(r >= th)[0]
            return int(idx[0] + 1) if idx.size else g.n_iters
        rows.append({
            "algorithm": algorithm, "total_iters": g.n_iters,
            "iters_to_95": first_at(0.95), "iters_to_99": first_at(0.99),
            "frac_iters_for_last_1pct":
                round(1 - first_at(0.99) / g.n_iters, 3),
        })
    return rows


# --------------------------------------------------------------------------
# Fig. 6 — the regression model (3D Road Network k=4)
# --------------------------------------------------------------------------

@bench("fig6_regression")
def fig6_regression():
    """h(r) regression per algorithm; paper: h = 1.83r² − 3.66r + 1.83
    (k-means, 3D Road k=4).  Coefficients are data-scale dependent — the
    claim validated here is the *form*: quadratic, h(1)≈0, R² high."""
    from benchmarks.paper_experiments import experiment
    rows = []
    for algorithm in ("kmeans", "em"):
        model, *_ = experiment("3D_Road/4", algorithm)
        c = model.regression.coeffs
        rows.append({
            "algorithm": algorithm, "family": model.regression.family,
            "b0": round(c[0], 6), "b1": round(c[1], 6),
            "b2": round(c[2], 6) if len(c) > 2 else "",
            "r2": round(model.regression.metrics.r2, 4),
            "h_at_r1": round(float(model.regression.predict(1.0)), 8),
        })
    return rows


@bench("model_selection")
def model_selection():
    """§4/§5.5-internal: quadratic vs linear/cubic/exp/lasso by adj-R²."""
    from benchmarks.paper_experiments import experiment, fit_model
    from repro.core import select_model, pool_traces, rh_from_objectives
    rows = []
    for algorithm in ("kmeans", "em"):
        model, train_runs, _, _ = experiment("3D_Road/4", algorithm)
        traces = [(g.accuracies[1:], rh_from_objectives(g.objectives))
                  for g in train_runs]
        r, h = pool_traces(traces)
        _, table = select_model(r, h)
        for fam, m in table.items():
            rows.append({"algorithm": algorithm, "family": fam,
                         "adj_r2": round(m.adj_r2, 4),
                         "rmse": f"{m.rmse:.3e}"})
    return rows


# --------------------------------------------------------------------------
# Table 2 — desired accuracy → h* threshold
# --------------------------------------------------------------------------

@bench("table2_thresholds")
def table2_thresholds():
    from benchmarks.paper_experiments import experiment, ACCURACIES
    rows = []
    for algorithm in ("kmeans", "em"):
        model, *_ = experiment("3D_Road/4", algorithm)
        row = {"algorithm": algorithm}
        for a in ACCURACIES:
            row[f"h_at_{a}"] = f"{model.threshold_for(a):.3e}"
        rows.append(row)
    return rows


# --------------------------------------------------------------------------
# Tables 3 & 4 — achieved accuracy per dataset × desired accuracy
# --------------------------------------------------------------------------

def _achieved(algorithm: str, family="quadratic", balanced=False):
    from benchmarks.paper_experiments import (experiment, ACCURACIES,
                                              DATASETS)
    rows = []
    means = {a: [] for a in ACCURACIES}
    for name in DATASETS:
        model, _, val_runs, k = experiment(name, algorithm, family=family,
                                           balanced=balanced)
        row = {"dataset": name}
        for a in ACCURACIES:
            h_star = model.threshold_for(a)
            achieved = [g.accuracies[g.stop_index(h_star)] for g in val_runs]
            row[f"acc_{a}"] = round(float(np.mean(achieved)), 4)
            row[f"std_{a}"] = round(float(np.std(achieved)), 4)
            means[a].append(float(np.mean(achieved)))
        rows.append(row)
    avg = {"dataset": "Average"}
    for a in ACCURACIES:
        avg[f"acc_{a}"] = round(float(np.mean(means[a])), 4)
        avg[f"std_{a}"] = ""
    rows.append(avg)
    return rows


@bench("table3_achieved_kmeans")
def table3_achieved_kmeans():
    """Paper-faithful: raw cloud, quadratic (Eq. 8)."""
    return _achieved("kmeans")


@bench("table4_achieved_em")
def table4_achieved_em():
    return _achieved("em")


@bench("table3b_kmeans_balanced_auto")
def table3b_kmeans_balanced_auto():
    """Beyond-paper: balanced cloud + model auto-selection (incl. log-quad)."""
    return _achieved("kmeans", family=None, balanced=True)


@bench("table4b_em_balanced_auto")
def table4b_em_balanced_auto():
    return _achieved("em", family=None, balanced=True)


# --------------------------------------------------------------------------
# Fig. 7 — cost-effectiveness (% of full computation time)
# --------------------------------------------------------------------------

@bench("fig7_cost_effectiveness")
def fig7_cost_effectiveness():
    from benchmarks.paper_experiments import (experiment, ACCURACIES,
                                              DATASETS)
    rows = []
    for algorithm in ("kmeans", "em"):
        fracs = {a: [] for a in ACCURACIES}
        for name in DATASETS:
            model, _, val_runs, k = experiment(name, algorithm)
            for a in ACCURACIES:
                h_star = model.threshold_for(a)
                for g in val_runs:
                    # iteration count as the time proxy (§3.3: time ∝ cost;
                    # per-iteration cost is constant for fixed n, k)
                    fracs[a].append((g.stop_index(h_star) + 1) / g.n_iters)
        row = {"algorithm": algorithm}
        for a in ACCURACIES:
            row[f"time_frac_{a}"] = round(float(np.mean(fracs[a])), 4)
        rows.append(row)
    return rows


# --------------------------------------------------------------------------
# §5.4 — the land-use case study (cloud cost)
# --------------------------------------------------------------------------

@bench("case_study_landuse")
def case_study_landuse():
    import jax.numpy as jnp
    import jax
    from repro import core
    from repro.core import landuse_case_study
    from repro.data import spacenet_pixels
    from repro.core.cost_model import US_AREA_KM2, CALIFORNIA_AREA_KM2

    # measure per-image full-convergence time on THIS machine (reduced res,
    # scaled up quadratically to 438×406 ≈ 177,828 px)
    pix = spacenet_pixels(n_images=2, k_true=6, seed=0, shape=(72, 72, 3))
    x = jnp.asarray(pix[0])
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(0), x, 6)
    core.kmeans_fit_full(x, c0, max_iters=200)[1].block_until_ready()  # warm
    t0 = time.time()
    _, _, _, iters_full = core.kmeans_fit_full(x, c0, max_iters=200)
    t_full_small = time.time() - t0
    scale = (438 * 406) / (72 * 72)
    t_full_image = t_full_small * scale

    res = core.kmeans_fit_traced(x, c0, max_iters=200)
    r, h = core.trace_to_rh(res, 6)
    model = core.fit_longtail([(np.asarray(r), np.asarray(h))],
                              algorithm="kmeans", dataset="spacenet",
                              family="quadratic")
    hh = core.rh_from_objectives(res["objectives"])
    idx = np.where(hh <= model.threshold_for(0.99))[0]
    frac = (int(idx[0]) + 2) / res["n_iters"] if idx.size else 1.0

    rows = []
    for area, label in ((CALIFORNIA_AREA_KM2, "california"),
                        (US_AREA_KM2, "united_states")):
        rep = landuse_case_study(t_full_image, frac, area_km2=area)
        rows.append({
            "region": label, "cost_effectiveness": round(frac, 4),
            "t_full_per_image_s": round(t_full_image, 3),
            "cost_full_usd": round(rep.cost_full_usd, 2),
            "savings_usd": round(rep.savings_usd, 2),
            "train_cost_usd": round(rep.cost_train_usd, 4),
        })
    return rows


# --------------------------------------------------------------------------
# Kernel microbenches (CSV: name,us_per_call,derived)
# --------------------------------------------------------------------------

@bench("kernels")
def kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
    from repro.kernels.gmm_estep.ref import gmm_estep_ref
    from repro.models.layers import _sdpa, _sdpa_chunked

    rng = np.random.default_rng(0)
    rows = []

    def timeit(fn, *args, n=5):
        # shared methodology (benchmarks.timing): warmup + block_until_ready
        return time_callable(fn, *args, reps=n, warmup=1,
                             reduce="mean") * 1e6

    x = jnp.asarray(rng.normal(0, 5, (100_000, 4)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 5, (8, 4)).astype(np.float32))
    us = timeit(jax.jit(kmeans_assign_ref), x, c)
    flops = 2 * 100_000 * 8 * 4 * 2
    rows.append({"name": "kmeans_assign_jnp_100k_d4_k8",
                 "us_per_call": round(us, 1),
                 "derived": f"{flops / us * 1e-3:.2f}GFLOPs"})

    mu = jnp.asarray(rng.normal(0, 2, (8, 4)).astype(np.float32))
    var = jnp.ones((8, 4), jnp.float32)
    lw = jnp.log(jnp.full((8,), 0.125, jnp.float32))
    us = timeit(jax.jit(gmm_estep_ref), x, mu, var, lw)
    rows.append({"name": "gmm_estep_jnp_100k_d4_k8",
                 "us_per_call": round(us, 1),
                 "derived": f"{3 * flops / us * 1e-3:.2f}GFLOPs"})

    q = jnp.asarray(rng.normal(0, 1, (1, 2048, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 2048, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 2048, 2, 64)).astype(np.float32))
    f_exact = jax.jit(lambda q, k, v: _sdpa(q, k, v, causal=True, window=None))
    f_chunk = jax.jit(lambda q, k, v: _sdpa_chunked(q, k, v, causal=True,
                                                    window=None))
    us_e = timeit(f_exact, q, k, v, n=3)
    us_c = timeit(f_chunk, q, k, v, n=3)
    rows.append({"name": "attention_exact_s2048", "us_per_call": round(us_e, 1),
                 "derived": "materialises SxS"})
    rows.append({"name": "attention_chunked_s2048",
                 "us_per_call": round(us_c, 1),
                 "derived": f"{us_e / us_c:.2f}x_vs_exact_O(S)_mem"})
    return rows


# --------------------------------------------------------------------------
# Unified engine: streaming chunk sweep + vmapped multi-restart
# --------------------------------------------------------------------------

@bench("engine_scaling")
def engine_scaling():
    """Streaming sweep cost vs chunk count (peak [N,K] intermediate shrinks
    by C) and vmapped multi-restart vs R sequential fits."""
    import jax
    import jax.numpy as jnp
    from repro import core
    from repro.core.engine import ClusteringEngine, EngineConfig

    rng = np.random.default_rng(0)
    n, d, k = 200_000, 8, 16
    x = jnp.asarray(rng.normal(0, 5, (n, d)).astype(np.float32))
    c0 = core.random_init(jax.random.PRNGKey(0), x, k)
    rows = []

    def timed(fn, *args, reps=3):
        return time_callable(fn, *args, reps=reps, warmup=1, reduce="mean")

    for chunks in (1, 8, 32):
        eng = ClusteringEngine("kmeans", EngineConfig(
            max_iters=10, chunks=chunks, use_h_stop=False,
            stop_when_frozen=True))
        s = timed(lambda: eng.fit(x, c0))
        rows.append({"name": f"kmeans_stream_c{chunks}_n200k_k16",
                     "s_per_fit": round(s, 4),
                     "derived": f"peak_NK={n // max(chunks, 1) * k}"})

    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=10, use_h_stop=False, stop_when_frozen=True))
    key = jax.random.PRNGKey(1)
    r = 4
    inits = eng.init_restarts(key, x, k, r)
    s_batch = timed(lambda: eng.fit_restarts(x, inits).best.labels)
    s_seq = timed(lambda: [eng.fit(x, jax.tree.map(lambda a: a[i], inits))
                           .labels for i in range(r)])
    rows.append({"name": f"kmeans_restarts_vmap_r{r}",
                 "s_per_fit": round(s_batch, 4),
                 "derived": f"{s_seq / max(s_batch, 1e-9):.2f}x_vs_sequential"})
    # s_seq times the whole r-fit loop; report it per fit like the others
    rows.append({"name": f"kmeans_restarts_seq_r{r}",
                 "s_per_fit": round(s_seq / r, 4),
                 "derived": f"baseline_total_{round(s_seq, 4)}s"})
    return rows


@bench("minibatch_scaling")
def minibatch_scaling():
    """Minibatch mode on a 2^18-point blob set: fraction of points touched
    per iteration vs accuracy (paper's r metric — Rand index against the
    full-batch partition).  The acceptance bar: ≥ 99% of full-batch accuracy
    while touching ≤ 25% of the points per iteration.

    ``points_per_iter_frac`` counts *distinct data touched* (B/C — the HBM
    streaming bound); ``sweep_equiv_compute_frac`` counts distance-pass
    compute, which is 2·B/C because the paired Eq. 7 stop evaluates the
    same subsample at the old and the new parameters each iteration."""
    import jax
    import jax.numpy as jnp
    from repro import core
    from repro.core.engine import ClusteringEngine, EngineConfig

    rng = np.random.default_rng(0)
    n, d, k, chunks = 1 << 18, 4, 8, 64
    centers = rng.normal(0, 6.0, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.5, (n // k, d)) for c in centers])
    x = jnp.asarray(x[rng.permutation(n)].astype(np.float32))  # unbias chunks
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(0), x, k, chunks=chunks)

    full = ClusteringEngine("kmeans", EngineConfig(
        max_iters=300, chunks=chunks, use_h_stop=False, stop_when_frozen=True))
    t0 = time.time()
    rf = full.fit(x, c0)
    jax.block_until_ready(rf.labels)
    t_full = time.time() - t0
    rows = [{"name": f"full_n{n}_k{k}", "iters": int(rf.n_iters),
             "points_per_iter_frac": 1.0, "sweep_equiv_compute_frac": 1.0,
             "j": round(float(rf.objective), 1),
             "rand_vs_full": 1.0, "fit_s": round(t_full, 3),
             "ge_99pct_at_le_25pct_touch": ""}]
    # 25% touch with mild forgetting (larger late steps), 12.5% with pure
    # 1/t annealing — both stop via the paired h, not max_iters
    for b, decay in ((16, 0.95), (8, 1.0)):
        mb = ClusteringEngine("kmeans", EngineConfig(
            mode="minibatch", chunks=chunks, batch_chunks=b, patience=5,
            max_iters=600, decay=decay, stop_when_frozen=True))
        t0 = time.time()
        rm = mb.fit(x, c0, h_star=1e-5)
        jax.block_until_ready(rm.labels)
        t_mb = time.time() - t0
        r = float(core.rand_index(rm.labels, rf.labels, k, k))
        frac = b / chunks
        rows.append({
            "name": f"minibatch_b{b}of{chunks}_n{n}_k{k}",
            "iters": int(rm.n_iters),
            "points_per_iter_frac": round(frac, 4),
            "sweep_equiv_compute_frac": round(2 * frac, 4),
            "j": round(float(rm.objective), 1),
            "rand_vs_full": round(r, 4), "fit_s": round(t_mb, 3),
            "ge_99pct_at_le_25pct_touch": bool(r >= 0.99 and frac <= 0.25),
        })
    return rows


@bench("minibatch_shard")
def minibatch_shard():
    """Sharded minibatch clustering across device counts (submeshes of the
    host platform): rand index vs the full-batch partition, sweep-equivalent
    compute fraction, and wall time per device count.

    Persists ``BENCH_minibatch_shard.json`` at the repo root — the
    perf-trajectory artifact the repo's history tracks (the CSVs under
    ``benchmarks/out/`` are per-run scratch).  Wall times on the forced
    host-platform device counts measure the collective + partitioning
    overhead of the composed path, not accelerator speedups.
    """
    import jax
    import jax.numpy as jnp
    from repro import compat  # noqa: F401  (make_mesh shim)
    from repro import core
    from repro.core.engine import ClusteringEngine, EngineConfig

    rng = np.random.default_rng(0)
    n, d, k, chunks, b = 1 << 18, 4, 8, 64, 16   # = minibatch_scaling's set
    centers = rng.normal(0, 6.0, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.5, (n // k, d)) for c in centers])
    x = jnp.asarray(x[rng.permutation(n)].astype(np.float32))
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(0), x, k,
                                    chunks=chunks)

    full = ClusteringEngine("kmeans", EngineConfig(
        max_iters=300, chunks=chunks, use_h_stop=False,
        stop_when_frozen=True))
    rf = full.fit(x, c0)
    jax.block_until_ready(rf.labels)

    # decay 0.95 = the minibatch_scaling 25%-touch recipe (mild forgetting
    # keeps late steps large enough to land ≥99% of full-batch accuracy)
    eng = ClusteringEngine("kmeans", EngineConfig(
        mode="minibatch", chunks=chunks, batch_chunks=b, patience=5,
        max_iters=600, decay=0.95, stop_when_frozen=True))
    devs = jax.devices()
    counts = [m for m in (1, 2, 4, 8) if m <= len(devs)]
    skipped = [m for m in (1, 2, 4, 8) if m > len(devs)]
    rows = []
    for m in counts:
        mesh = jax.make_mesh((m,), ("data",), devices=devs[:m],
                             axis_types=(jax.sharding.AxisType.Auto,))
        res = eng.fit_sharded(x, c0, mesh, h_star=1e-5)   # compile + warm
        jax.block_until_ready(res.labels)
        wall = time_callable(
            lambda: eng.fit_sharded(x, c0, mesh, h_star=1e-5).labels,
            reps=1, warmup=0)
        r = float(core.rand_index(res.labels, rf.labels, k, k))
        rows.append({
            "name": f"minibatch_shard_d{m}", "devices": m,
            "iters": int(res.n_iters),
            "rand_vs_full": round(r, 4),
            "sweep_equiv_compute_frac": round(2 * b / chunks, 4),
            "wall_s_fit": round(wall, 3),
        })

    if skipped:
        # never silently overwrite the tracked multi-device trajectory with
        # a partial sweep — say what's missing and keep the old artifact
        print(f"# minibatch_shard: only {len(devs)} device(s) visible, "
              f"skipped counts {skipped}; NOT writing "
              "BENCH_minibatch_shard.json (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8 for the full sweep)")
        return rows
    payload = {
        "benchmark": "minibatch_shard",
        "n": n, "d": d, "k": k, "chunks": chunks, "batch_chunks": b,
        "decay": 0.95,
        "note": "device counts are XLA host-platform emulation "
                "(--xla_force_host_platform_device_count); wall times "
                "measure collective/partitioning overhead on CPU, not "
                "accelerator scaling",
        "rows": rows,
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_minibatch_shard.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {path}")
    return rows


@bench("sharded_overlap")
def sharded_overlap():
    """ISSUE 7: compressed, latency-hidden sharded sweeps — int8-EF ring
    stats reduction vs fp32 psum, and the latency-hiding toggle (XLA flags
    + double-buffered chunk prefetch) vs the synchronous baseline.

    XLA reads ``XLA_FLAGS`` once per process, so each flag leg runs in a
    fresh worker subprocess (``benchmarks.sharded_overlap_worker``) whose
    environment ``repro.launch.mesh.overlap_env`` builds; the overlap leg
    also turns on ``EngineConfig(prefetch=True)`` (bit-identical math).

    Persists ``BENCH_sharded_overlap.json`` at the repo root (tracked
    artifact).  Tracked claims (the CI ``longtail-artifacts`` gate):

      · parity — int8-EF stop iterations match the fp32 psum stop to
        ≤ 1 iteration at every device count, in both legs (the centred
        compression basis + error feedback keep the Eq. 7 h trajectory on
        the fp32 one);
      · ≥ 3× collective-byte reduction vs fp32 at every multi-device
        count (analytic ``stats_wire_bytes``; the ring factor cancels);
      · overlap wall-clock per sweep no worse than the synchronous
        baseline, summed over the sweep grid (1.15× tolerance — CPU
        host-emulation timing noise, not a perf regression bar).
    """
    import subprocess
    import sys
    import tempfile
    import jax
    from repro.launch.mesh import overlap_env

    if len(jax.devices()) < 8:
        print("# sharded_overlap: needs 8 devices (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8); skipping — NOT "
              "writing BENCH_sharded_overlap.json")
        return []

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    legs = {}
    for leg, enable in (("sync", False), ("overlap", True)):
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
            out = tf.name
        cmd = [sys.executable, "-m", "benchmarks.sharded_overlap_worker",
               "--out", out, "--leg", leg] + (["--prefetch"] if enable
                                              else [])
        subprocess.run(cmd, check=True, cwd=root,
                       env=overlap_env(enable=enable))
        with open(out) as f:
            legs[leg] = json.load(f)
        os.unlink(out)

    rows = [r for leg in ("sync", "overlap") for r in legs[leg]["rows"]]
    cell = {(r["leg"], r["devices"], r["compression"]): r for r in rows}
    counts = sorted({r["devices"] for r in rows})
    parity = {f"{leg}_d{m}": abs(cell[(leg, m, "int8_ef")]["iters"]
                                 - cell[(leg, m, "none")]["iters"])
              for leg in ("sync", "overlap") for m in counts}
    byte_ratio = {f"d{m}": round(
        cell[("sync", m, "none")]["wire_bytes_per_reduction"]
        / cell[("sync", m, "int8_ef")]["wire_bytes_per_reduction"], 3)
        for m in counts if m > 1}
    wall = {leg: round(sum(r["wall_s"] for r in legs[leg]["rows"]), 3)
            for leg in ("sync", "overlap")}
    payload = {
        "benchmark": "sharded_overlap",
        **{k: legs["sync"][k] for k in ("n", "d", "k", "chunks",
                                        "batch_chunks", "h_star",
                                        "timed_iters")},
        "overlap_leg": {"xla_flags": "latency_hiding_xla_flags",
                        "prefetch": True},
        "parity_iters_delta": parity,
        "wire_byte_ratio_fp32_over_int8": byte_ratio,
        "timed_wall_s_total": wall,
        "claims": {
            "int8_parity_delta_le_1": bool(max(parity.values()) <= 1),
            "wire_byte_reduction_ge_3x":
                bool(min(byte_ratio.values()) >= 3.0),
            "overlap_wall_no_worse_1p15x":
                bool(wall["overlap"] <= wall["sync"] * 1.15),
        },
        "note": "device counts are XLA host-platform emulation on CPU; "
                "wall columns measure collective/partitioning overhead, "
                "not accelerator scaling.  Parity and byte-ratio columns "
                "are host-independent (the tracked claims); the wall "
                "claim carries a 1.15x noise tolerance",
        "rows": rows,
    }
    path = os.path.join(root, "BENCH_sharded_overlap.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {path}")
    return rows


@bench("kernel_backends")
def kernel_backends():
    """ISSUE 4: the kernel dispatch layer across engine modes and device
    counts — full vs minibatch sweeps, dispatched kernel (interpret on this
    host; the same code compiles on TPU/GPU) vs the XLA reference backend,
    single-device and sharded.

    Persists ``BENCH_kernel_backends.json`` at the repo root (tracked
    perf-trajectory artifact, like ``BENCH_minibatch_shard.json``).  Wall
    times on a CPU host measure the interpreter + partitioning overhead of
    the composed path, not accelerator speedups — the artifact's tracked
    claims are the parity columns (identical stop iterations and matching
    objectives across backends), which hold on any host.
    """
    import jax
    import jax.numpy as jnp
    from repro import compat  # noqa: F401  (make_mesh shim)
    from repro import core
    from repro.core.engine import ClusteringEngine, EngineConfig

    rng = np.random.default_rng(0)
    n, d, k, chunks, b = 1 << 15, 4, 8, 16, 4     # 25% touch in minibatch
    centers = rng.normal(0, 6.0, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.5, (n // k, d)) for c in centers])
    x = jnp.asarray(x[rng.permutation(n)].astype(np.float32))
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(0), x, k,
                                    chunks=chunks)
    devs = jax.devices()
    counts = [m for m in (1, 2, 4, 8) if m <= len(devs)]

    def cfg(mode, backend):
        kw = dict(max_iters=300, chunks=chunks, stop_when_frozen=True,
                  use_kernel=True, kernel_backend=backend)
        if mode == "minibatch":
            kw.update(mode="minibatch", batch_chunks=b, patience=5,
                      max_iters=600, decay=0.95)
            return EngineConfig(**kw)
        kw.update(use_h_stop=False)
        return EngineConfig(**kw)

    def fit(engine, mesh=None):
        # 1e-4 trips the paired minibatch stop well before max_iters (~130
        # iterations here), so the parity column compares real early-stop
        # decisions, not a trivial run-to-max; full mode stops on frozen
        # centroids (use_h_stop=False) and ignores the threshold
        run = (lambda: engine.fit(x, c0, h_star=1e-4)) if mesh is None else \
            (lambda: engine.fit_sharded(x, c0, mesh, h_star=1e-4))
        res = run()                                   # compile + warm
        jax.block_until_ready(res.labels)
        return res, time_callable(lambda: run().labels, reps=1, warmup=0)

    rows = []
    baselines = {}
    host_backend = "interpret" if jax.default_backend() == "cpu" \
        else jax.default_backend()
    for mode in ("full", "minibatch"):
        for backend in (host_backend, "xla"):
            eng = ClusteringEngine("kmeans", cfg(mode, backend))
            for m in counts:
                mesh = None if m == 1 else jax.make_mesh(
                    (m,), ("data",), devices=devs[:m],
                    axis_types=(jax.sharding.AxisType.Auto,))
                res, wall = fit(eng, mesh)
                key = (mode, m)
                base = baselines.setdefault(key, res)
                rows.append({
                    "name": f"{mode}_{backend}_d{m}",
                    "mode": mode, "backend": backend, "devices": m,
                    "iters": int(res.n_iters),
                    "j": round(float(res.objective), 1),
                    "stop_matches_first_backend":
                        bool(int(res.n_iters) == int(base.n_iters)),
                    "wall_s_fit": round(wall, 3),
                })

    skipped = [m for m in (1, 2, 4, 8) if m > len(devs)]
    if skipped:
        print(f"# kernel_backends: only {len(devs)} device(s) visible, "
              f"skipped counts {skipped}; NOT writing "
              "BENCH_kernel_backends.json (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8 for the full "
              "sweep)")
        return rows
    payload = {
        "benchmark": "kernel_backends",
        "n": n, "d": d, "k": k, "chunks": chunks, "batch_chunks": b,
        "host_pallas_backend": host_backend,
        "note": "device counts are XLA host-platform emulation; wall "
                "times on CPU measure interpreter/partitioning overhead, "
                "not accelerator scaling — the tracked claim is backend "
                "parity (stop_matches_first_backend) per mode × device "
                "count",
        "rows": rows,
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_kernel_backends.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {path}")
    return rows


@bench("longtail_matched")
def longtail_matched():
    """ISSUE 5: mode-matched vs transferred h(r) fits on the skin config.

    Both models are fitted on the SAME training groups through the
    engine-trace pipeline (``repro.core.longtail_train``) — one harvested
    under the minibatch production config (matched), one under full-batch
    sweeps (the legacy transfer regime) — then both serve the SAME
    minibatch production runs on held-out groups at r* ∈ {0.95, 0.99}.
    Achieved accuracy = Rand index vs the group's full-convergence
    partition from the same init (the paper's §5.3 validation).

    Persists ``BENCH_longtail_matched.json`` at the repo root (tracked
    artifact).  Tracked claims: the matched fit's achieved-accuracy
    spread (max − min across held-out groups) at r* = 0.99 is ≤ the
    transferred fit's, and its mean achieved accuracy at r* = 0.95 clears
    0.95 (the CI ``longtail-artifacts`` gate).
    """
    import warnings

    import jax
    import jax.numpy as jnp
    from repro import core
    from repro.core.engine import ClusteringEngine, EngineConfig
    from repro.core.longtail_train import TrainingPlan, fit_for_config
    from repro.data import load

    k, chunks, b, decay = 2, 8, 2, 0.95
    data = load("skin", n=60_000, seed=0)
    groups = core.random_groups(data, 6_000, max_groups=8)
    train_g, prod_g = groups[:4], groups[4:]

    # decay 0.95 = the documented 25%-touch production recipe
    # (minibatch_scaling); both fits use the balanced r-binned cloud so the
    # transition region the thresholds live in is equally weighted — the
    # raw skin cloud puts almost all mass at r ≈ 1 and under-constrains
    # both regressions.
    prod_cfg = EngineConfig(mode="minibatch", chunks=chunks, batch_chunks=b,
                            decay=decay, patience=5, max_iters=400,
                            stop_when_frozen=True)
    models = {
        "matched": fit_for_config(TrainingPlan(
            algorithm="kmeans", k=k, config=prod_cfg, family="quadratic",
            balanced=True), train_g),
        "transferred": fit_for_config(TrainingPlan(
            algorithm="kmeans", k=k, config=EngineConfig(max_iters=400),
            family="quadratic", balanced=True), train_g),
    }

    # full-convergence reference partition per held-out group (same init)
    full = ClusteringEngine("kmeans", EngineConfig(
        max_iters=1200, chunks=chunks, use_h_stop=False,
        stop_when_frozen=True))
    inits, refs = [], []
    for gi, g in enumerate(prod_g):
        x = jnp.asarray(g)
        c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(100 + gi), x, k,
                                        chunks=chunks)
        inits.append(c0)
        refs.append(full.fit(x, c0).labels)

    prod_kw = dict(mode="minibatch", chunks=chunks, batch_chunks=b,
                   decay=decay, patience=5, max_iters=400,
                   stop_when_frozen=True)
    rows = []
    spreads = {}
    for r_star in (0.95, 0.99):
        for name, model in models.items():
            accs, iters = [], []
            for gi, g in enumerate(prod_g):
                with warnings.catch_warnings():
                    # the transferred model mismatches by design
                    warnings.simplefilter("ignore")
                    cfg = EngineConfig.from_longtail(
                        model, r_star, seed=100 + gi, **prod_kw)
                res = ClusteringEngine("kmeans", cfg).fit(
                    jnp.asarray(g), inits[gi])
                accs.append(float(core.rand_index(res.labels, refs[gi],
                                                  k, k)))
                iters.append(int(res.n_iters))
            spread = max(accs) - min(accs)
            spreads[(r_star, name)] = spread
            rows.append({
                "name": f"{name}_rstar{r_star}", "fit": name,
                "r_star": r_star,
                "h_star": f"{model.threshold_for(r_star):.3e}",
                "acc_mean": round(float(np.mean(accs)), 4),
                "acc_min": round(min(accs), 4),
                "acc_max": round(max(accs), 4),
                "spread": round(spread, 4),
                "mean_iters": round(float(np.mean(iters)), 1),
                "per_group_acc": "|".join(f"{a:.4f}" for a in accs),
            })

    payload = {
        "benchmark": "longtail_matched",
        "dataset": "skin", "k": k, "n": 60_000, "group_size": 6_000,
        "train_groups": 4, "prod_groups": len(prod_g),
        "production_config": prod_cfg.matched_fingerprint(),
        "matched_provenance": models["matched"].engine_config,
        "claims": {
            "matched_spread_le_transferred_at_0.99":
                bool(spreads[(0.99, "matched")]
                     <= spreads[(0.99, "transferred")]),
            "matched_acc_mean_at_0.95_ge_0.95":
                bool(next(r for r in rows
                          if r["name"] == "matched_rstar0.95")["acc_mean"]
                     >= 0.95),
        },
        "note": "achieved accuracy = Rand vs the full-convergence "
                "partition of the same held-out group and init; spread = "
                "max - min across held-out groups; both fits share "
                "training groups and differ only in harvest regime",
        "rows": rows,
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_longtail_matched.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {path}")
    return rows


# --------------------------------------------------------------------------
# Cost-aware provisioning planner: predicted vs actual (ISSUE 10 tentpole)
# --------------------------------------------------------------------------

@bench("plan")
def plan_bench():
    """ISSUE 10: planner predicted-vs-actual on the small skin config.

    Fits per-mode h(r) + iteration models from harvested traces, runs the
    planner at r* = 0.99 over the default price table and the committed
    throughput benches, then executes the chosen plan through the real fit
    drivers on a held-out group (``repro.launch.plan.validate_plan`` —
    warm walls, so Eq. 10 compares steady-state compute, plus the
    StragglerMonitor step-loop report).

    Persists ``BENCH_plan.json`` at the repo root (tracked artifact).
    Tracked claims (CI ``longtail-artifacts`` gate):
      · ``iters_within_tolerance`` — actual stop iterations within
        ±max(50%, 5) of predicted (host-independent, hard-gated);
      · ``actual_cost_below_full_convergence`` — the validated run's
        Eq. 6 cost at r* = 0.99 is strictly below the full-convergence
        reference on the same host (the paper's §5.4 claim, executable;
        warm same-host walls so host noise largely cancels);
      · ``predicted_cost_fraction_below_1`` — the planner already
        predicts that saving before running anything;
      · ``straggler_report_present`` — the monitored step-loop evidence
        landed (ISSUE 10 satellite: StragglerMonitor wired through
        --validate).
    Wall-seconds agreement is recorded but advisory: the throughput
    points were measured on a different host class than CI.
    """
    import jax.numpy as jnp
    from repro import core
    from repro.core.cost_model import PriceTable
    from repro.core.planner import PlanSpec, ThroughputModel
    from repro.core.planner import plan as run_plan
    from repro.data import load
    from repro.launch.plan import TOLERANCE, fit_models, validate_plan

    k, chunks, b, decay, max_iters, r_star = 2, 16, 4, 0.95, 200, 0.99
    data = load("skin", n=24_000, seed=0)
    groups = core.random_groups(data, 6_000, max_groups=3)
    train_g, val = groups[:2], jnp.asarray(groups[2], jnp.float32)

    models, ims = fit_models(train_g, algorithm="kmeans", k=k,
                             chunks=chunks, batch_chunks=b, decay=decay,
                             max_iters=max_iters, seed=0)
    prices = PriceTable.default()
    throughput = ThroughputModel.from_bench_dir()
    spec = PlanSpec(n=24_000, d=int(data.shape[1]), k=k, target_r=r_star,
                    deadline_s=3600.0, prices=prices, max_iters=max_iters,
                    chunks=chunks, batch_chunks=b, decay=decay)
    report = run_plan(spec, models=models, iteration_models=ims,
                      throughput=throughput)
    record = validate_plan(report, val, algorithm="kmeans", k=k,
                           models=models, throughput=throughput,
                           prices=prices, target_r=r_star,
                           max_iters=max_iters)

    chosen = report.chosen
    claims = {
        "iters_within_tolerance": bool(record["iters_within_tolerance"]),
        "actual_cost_below_full_convergence":
            bool(record["cost_fraction_actual"] < 1.0),
        "predicted_cost_fraction_below_1":
            bool(report.cost_fraction < 1.0),
        "straggler_report_present":
            bool(record["straggler"].get("steps", 0) > 0),
    }
    rows = [{
        "name": "plan_rstar0.99", "chosen": chosen.describe(),
        "predicted_iters": record["predicted"]["iters"],
        "actual_iters": record["actual"]["iters"],
        "predicted_cost_usd": f"{record['predicted']['cost_usd']:.3e}",
        "actual_cost_usd": f"{record['actual']['cost_usd']:.3e}",
        "cost_fraction_predicted": round(report.cost_fraction, 4),
        "cost_fraction_actual": round(record["cost_fraction_actual"], 4),
        "accuracy": round(record["actual"]["accuracy"], 4),
        "straggler_flagged": record["straggler"].get("flagged", 0),
    }]
    payload = {
        "benchmark": "plan",
        "dataset": "skin", "k": k, "n": 24_000, "group_size": 6_000,
        "train_groups": 2,
        "target_r": r_star, "deadline_s": 3600.0,
        "engine": {"chunks": chunks, "batch_chunks": b, "decay": decay,
                   "max_iters": max_iters},
        "price_table": [p.name for p in prices.prices],
        "h_star_by_mode": report.h_star_by_mode,
        "chosen": {
            "candidate": chosen.describe(),
            "engine_kwargs": chosen.engine_kwargs(),
            "predicted_iters": chosen.predicted_iters,
            "predicted_wall_s": chosen.predicted_wall_s,
            "predicted_cost_usd": chosen.predicted_cost_usd,
        },
        "cost_fraction_predicted": report.cost_fraction,
        "full_reference": report.full_reference,
        "tolerance": TOLERANCE,
        "validation": record,
        "claims": claims,
        "note": "validation walls are warm (second call of an identical "
                "jit program) so Eq. 10 compares steady-state compute; "
                "wall-seconds agreement with the cross-host throughput "
                "points is advisory, iteration and same-host cost-"
                "fraction claims are the CI gate",
        "rows": rows,
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_plan.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {path}")
    return rows


# --------------------------------------------------------------------------
# Clustering-as-a-service: the assignment server (ISSUE 6 tentpole)
# --------------------------------------------------------------------------

@bench("serve_cluster")
def serve_cluster():
    """Continuous-batching assignment server: per-model latency,
    throughput, QPS and the recompile-count claim.

    Two artifacts with real harvest provenance (minibatch k-means +
    full-batch EM, ``launch.serve_cluster.demo_artifacts``) serve a mixed
    stream of assignment batches in several drain waves, plus incremental
    fit jobs.  Persists ``BENCH_serve_cluster.json`` at the repo root.
    Tracked claims (the CI ``longtail-artifacts`` gate):

      · one compiled program per (model, bucket) — the assign jit cache
        never exceeds the bucket count, no matter how many distinct batch
        sizes arrive;
      · served labels match ``ClusteringEngine`` batch assignment
        bit-for-bit (padding never leaks into results).
    """
    import jax
    import numpy as np
    from repro.core.engine import ClusteringEngine
    from repro.launch.serve_cluster import demo_artifacts
    from repro.serving import AssignRequest, ClusterServer, FitRequest, \
        ModelRegistry

    buckets = (256, 1024, 4096)
    registry = ModelRegistry(devices=len(jax.devices()), fit_steps=20)
    artifacts = demo_artifacts(seed=0)
    keys = {a.name: registry.register(a) for a in artifacts}
    server = ClusterServer(registry, buckets=buckets)
    for key in keys.values():
        server.warmup(key)              # steady-state latencies only

    rng = np.random.default_rng(0)
    d = artifacts[0].d
    names = list(keys)
    rid = 0
    labels_match = True
    parity_checks = 0
    for wave in range(6):
        wave_reqs = []
        for _ in range(12):
            name = names[rng.integers(0, len(names))]
            n = int(rng.integers(20, 3000))
            wave_reqs.append(AssignRequest(
                x=rng.normal(0, 4, (n, d)).astype(np.float32),
                model_key=keys[name], rid=rid))
            rid += 1
        if wave % 3 == 2:               # fits are rare — the paper's premise
            name = names[rng.integers(0, len(names))]
            wave_reqs.append(FitRequest(
                x=rng.normal(0, 4, (512, d)).astype(np.float32),
                model_key=keys[name], rid=rid))
            rid += 1
        for r in wave_reqs:
            server.submit(r)
        out = server.drain()
        # spot-check label parity against the engine's batch assignment
        for r in wave_reqs[:2]:
            if not isinstance(r, AssignRequest):
                continue
            entry = server.registry[r.model_key]
            eng = ClusteringEngine(entry.artifact.algorithm, entry.config)
            _, ref, _ = eng.step(r.x, entry.params)
            labels_match &= bool(np.array_equal(out[r.rid], np.asarray(ref)))
            parity_checks += 1

    compiled = server.compiled_programs()
    one_per_bucket = all(c["assign"] <= len(buckets)
                         for c in compiled.values())
    rows = []
    for a in artifacts:
        key = keys[a.name]
        m = server.metrics.summary()[key]
        fit_m = server.metrics.summary().get(f"{key}#fit")
        rows.append({
            "model": a.name, "algorithm": a.algorithm,
            "requests": m["requests"], "batches": m["batches"],
            "points": m["points"],
            "p50_latency_ms": round(m["p50_latency_ms"], 3),
            "p99_latency_ms": round(m["p99_latency_ms"], 3),
            "throughput_points_per_s":
                round(m["throughput_points_per_s"], 1),
            "qps": round(m["qps"], 2),
            "fit_jobs": fit_m["requests"] if fit_m else 0,
            "compiled_assign": compiled[key]["assign"],
            "compiled_fit": compiled[key]["fit"],
        })

    payload = {
        "benchmark": "serve_cluster",
        "buckets": list(buckets),
        "devices": len(jax.devices()),
        "parity_checks": parity_checks,
        "claims": {
            "one_program_per_model_bucket": bool(one_per_bucket),
            "served_labels_match_engine": bool(labels_match),
        },
        "note": "latencies are steady-state (buckets pre-compiled via "
                "warmup); one compiled assign program per (model, bucket) "
                "regardless of arriving batch sizes; fit jobs advance the "
                "registered params under the artifact's own engine regime",
        "models": {a.name: {"key": keys[a.name],
                            "provenance": a.model.engine_config}
                   for a in artifacts},
        "rows": rows,
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_serve_cluster.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {path}")
    return rows


# --------------------------------------------------------------------------
# Roofline table (reads experiments/dryrun/*.json → §Roofline source data)
# --------------------------------------------------------------------------

@bench("roofline_table")
def roofline_table():
    rows = []
    src = next(d for d in ("experiments/dryrun_v3", "experiments/dryrun_v2",
                           "experiments/dryrun")
               if glob.glob(d + "/*.json"))
    for path in sorted(glob.glob(f"{src}/*.json")):
        with open(path) as f:
            d = json.load(f)
        if "error" in d:
            rows.append({"cell": os.path.basename(path)[:-5], "status": "ERROR",
                         "compute_s": "", "memory_s": "", "collective_s": "",
                         "dominant": "", "useful_ratio": "", "hbm_gib": ""})
            continue
        r = d["roofline"]
        mem = d["memory"]
        hbm = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
               + mem["output_size_in_bytes"] - mem["alias_size_in_bytes"])
        rows.append({
            "cell": f"{d['arch']}__{d['shape']}__{d['mesh']}",
            "status": "OK",
            "compute_s": round(r["compute_s"], 4),
            "memory_s": round(r["memory_s"], 4),
            "collective_s": round(r["collective_s"], 4),
            "dominant": r["dominant"],
            "useful_ratio": round(r["useful_ratio"], 3),
            "hbm_gib": round(hbm / 2**30, 2),
        })
    return rows


@bench("perf_compare")
def perf_compare():
    """§Perf table: baseline (dryrun_v3, optimizations off) vs optimized
    (perf_v3) under the same trip-count-aware cost model."""
    cells = [
        ("xlstm-350m__train_4k", "chunkwise mLSTM L=128"),
        ("qwen3-moe-30b-a3b__prefill_32k", "grouped dispatch G=16"),
        ("gemma3-12b__decode_32k", "ring window caches"),
    ]
    rows = []
    for cell, change in cells:
        for mesh in ("16x16", "pod2x16x16"):
            try:
                def first(*paths):
                    for q in paths:
                        if os.path.exists(q):
                            with open(q) as f:
                                return json.load(f)
                    raise FileNotFoundError(paths)
                b = first(f"experiments/dryrun_v4/{cell}__{mesh}.json",
                          f"experiments/dryrun_v3/{cell}__{mesh}.json")
                o = first(f"experiments/perf_v4/{cell}__{mesh}.json",
                          f"experiments/perf_v3/{cell}__{mesh}.json")
            except FileNotFoundError:
                continue
            br, orr = b["roofline"], o["roofline"]
            bm = b["memory"]; om = o["memory"]
            gib = lambda m: (m["argument_size_in_bytes"] + m["temp_size_in_bytes"]
                             + m["output_size_in_bytes"]
                             - m["alias_size_in_bytes"]) / 2**30
            dom = br["dominant"] + "_s"
            rows.append({
                "cell": f"{cell}__{mesh}", "change": change,
                "dominant": br["dominant"],
                "before_s": round(br[dom], 4), "after_s": round(orr[dom], 4),
                "speedup": round(br[dom] / max(orr[dom], 1e-9), 1),
                "mem_gib_before": round(gib(bm), 1),
                "mem_gib_after": round(gib(om), 1),
                "useful_before": round(br["useful_ratio"], 3),
                "useful_after": round(orr["useful_ratio"], 3),
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(_REGISTRY)
    t0 = time.time()
    for name in names:
        t1 = time.time()
        rows = _REGISTRY[name]()
        _emit(name, rows)
        print(f"# {name} took {time.time() - t1:.1f}s")
    print(f"\n# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
