"""Shared machinery for the paper-table benchmarks: run the §4 pipeline on a
dataset config and collect per-group results (reduced-scale datasets;
structure identical to the paper's §5)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import em_gmm
from repro.data import load, spacenet_pixels

ACCURACIES = (0.90, 0.95, 0.99, 0.999)

# Reduced-scale mirrors of the paper's Table 1 setups.
DATASETS = {
    "3D_Road/4": dict(dataset="road3d", k=4, n=20_000, group_size=4_000),
    "3D_Road/8": dict(dataset="road3d", k=8, n=20_000, group_size=4_000),
    "Skin_Seg/2": dict(dataset="skin", k=2, n=20_000, group_size=4_000),
    "Poker_Hand/10": dict(dataset="poker", k=10, n=15_000, group_size=3_000),
    "SpaceNet/6": dict(dataset="spacenet", k=6, n=None, group_size=None),
}


@dataclasses.dataclass
class GroupRun:
    """One validation group, run to convergence once; early-stop points are
    then *replayed* from the recorded history (no re-clustering per
    accuracy level — matches how the paper evaluates Tables 3/4)."""
    objectives: np.ndarray       # J_i
    accuracies: np.ndarray       # r_i vs final partition
    times: np.ndarray            # cumulative wall time proxy (iterations)
    n_iters: int

    def stop_index(self, h_star: float) -> int:
        js = self.objectives
        h = np.abs(np.diff(js)) / np.maximum(np.abs(js[:-1]), 1e-30)
        idx = np.where(h <= h_star)[0]
        return int(idx[0] + 1) if idx.size else self.n_iters - 1


def load_groups(name: str, seed: int = 0, max_groups: int = 7):
    spec = DATASETS[name]
    if spec["dataset"] == "spacenet":
        pix = spacenet_pixels(n_images=max_groups, k_true=spec["k"],
                              seed=seed, shape=(72, 72, 3))
        return pix, spec["k"]
    data = load(spec["dataset"], n=spec["n"], seed=seed)
    groups = core.random_groups(data, spec["group_size"], seed=seed,
                                max_groups=max_groups)
    return groups, spec["k"]


def run_group(x, k: int, algorithm: str, seed: int,
              max_iters: int = 250) -> GroupRun:
    xj = jnp.asarray(x)
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(seed), xj, k)
    t0 = time.time()
    if algorithm == "kmeans":
        res = core.kmeans_fit_traced(xj, c0, max_iters=max_iters)
    else:
        # tol 1e-6: Matlab gmdistribution's default — the paper's setup
        p0 = em_gmm.init_from_kmeans(xj, c0)
        res = em_gmm.em_fit_traced(xj, p0, max_iters=max_iters, tol=1e-6)
    r = core.trace_accuracy(res["labels_history"], k)
    n = res["n_iters"]
    return GroupRun(objectives=np.asarray(res["objectives"]),
                    accuracies=np.asarray(r),
                    times=np.linspace(0, time.time() - t0, n),
                    n_iters=n)


def fit_model(runs: list[GroupRun], algorithm: str,
              family: str | None = "quadratic", balanced: bool = False):
    traces = []
    for g in runs:
        js = g.objectives
        h = np.abs(np.diff(js)) / np.maximum(np.abs(js[:-1]), 1e-30)
        traces.append((g.accuracies[1:], h))
    return core.fit_longtail(traces, algorithm=algorithm, dataset="bench",
                             family=family, balanced=balanced)


_RUN_CACHE: dict = {}


def experiment(name: str, algorithm: str, *, seed: int = 0,
               max_iters: int = 250, family: str | None = "quadratic",
               balanced: bool = False):
    """Full pipeline for one dataset: train on groups[:-2], validate on the
    last two.  Returns (model, train_runs, val_runs, k).  Group runs are
    cached per (dataset, algorithm) — refits are cheap."""
    key = (name, algorithm, seed, max_iters)
    if key not in _RUN_CACHE:
        groups, k = load_groups(name, seed)
        runs = [run_group(groups[i], k, algorithm, seed=seed * 17 + i,
                          max_iters=max_iters)
                for i in range(groups.shape[0])]
        _RUN_CACHE[key] = (runs, k)
    runs, k = _RUN_CACHE[key]
    model = fit_model(runs[:-2], algorithm, family=family, balanced=balanced)
    return model, runs[:-2], runs[-2:], k
