"""Worker process for the ``sharded_overlap`` benchmark.

XLA parses ``XLA_FLAGS`` exactly once at backend initialisation, so the
latency-hiding-flag toggle can only be profiled across *processes*: the
parent bench (``benchmarks.run sharded_overlap``) spawns this module once
per flag leg through ``repro.launch.mesh.overlap_env`` and merges the
JSON each worker writes.

One leg sweeps device counts × ``stats_compression`` on the minibatch
k-means recipe (the ``minibatch_shard`` set at d=8):

  · parity fit — the engine's paired Eq. 7 early stop at an h* in the
    steep decay region; the stop iteration is the tracked parity claim
    (int8 ring vs fp32 psum must agree to ≤ 1 iteration).
  · timed fit — both stops disabled, fixed trip count, so wall / iters
    is a clean seconds-per-sweep column comparable across legs.
  · wire bytes — ``stats_wire_bytes``'s analytic bytes-on-wire per
    reduction (the ring factor is identical for both compressions, so
    the int8-vs-fp32 ratio is exact).

The ``--prefetch`` flag rides with the overlap leg: double-buffered chunk
loads are bit-identical math, so parity columns stay comparable while the
scheduler gets the overlap opportunity the flags are meant to exploit.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.timing import time_callable


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--leg", required=True, choices=["sync", "overlap"])
    ap.add_argument("--prefetch", action="store_true")
    ap.add_argument("--timed-iters", type=int, default=40)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import compat  # noqa: F401  (shard_map / make_mesh shims)
    from repro import core
    from repro.core.engine import (ClusteringEngine, EngineConfig,
                                   get_algorithm, stats_wire_bytes)

    rng = np.random.default_rng(0)
    n, d, k, chunks, b = 1 << 18, 8, 8, 64, 16
    centers = rng.normal(0, 6.0, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.5, (n // k, d)) for c in centers])
    x = jnp.asarray(x[rng.permutation(n)].astype(np.float32))
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(0), x, k,
                                    chunks=chunks)
    zero = get_algorithm("kmeans").zero_stats(c0)

    def cfg(compression, timed):
        # both fits share the production minibatch recipe; the timed fit
        # disables every stop so all cells run the same trip count and
        # wall / iters is per-sweep time, not a stop-decision artifact.
        # stop_when_frozen stays off in the parity fit too: int8-quantised
        # stats never bit-freeze (EngineConfig rejects the combination),
        # and the parity claim is about the paired-h stop.
        kw = dict(mode="minibatch", chunks=chunks, batch_chunks=b,
                  decay=0.95, patience=5, seed=0, stop_when_frozen=False,
                  stats_compression=compression, prefetch=args.prefetch)
        if timed:
            kw.update(max_iters=args.timed_iters, use_h_stop=False)
        else:
            kw.update(max_iters=600)
        return EngineConfig(**kw)

    devs = jax.devices()
    counts = [m for m in (1, 2, 4, 8) if m <= len(devs)]
    rows = []
    for m in counts:
        mesh = jax.make_mesh((m,), ("data",), devices=devs[:m],
                             axis_types=(jax.sharding.AxisType.Auto,))
        for compression in ("none", "int8_ef"):
            # h* = 3e-3 crosses while h is still in steep decay: the stop
            # margin dwarfs both int8 rounding and fp32 reduction-order
            # noise (deeper thresholds sit where sweep-to-sweep h jitter
            # is the same size as h itself and parity degrades to ±2)
            eng = ClusteringEngine("kmeans", cfg(compression, timed=False))
            res = eng.fit_sharded(x, c0, mesh, h_star=3e-3)
            jax.block_until_ready(res.labels)

            timed = ClusteringEngine("kmeans", cfg(compression, timed=True))
            rt = timed.fit_sharded(x, c0, mesh)          # compile + warm
            jax.block_until_ready(rt.labels)
            # min-of-3: squeeze out host scheduling noise, the CPU
            # substrate's dominant timing artifact
            wall = time_callable(
                lambda: timed.fit_sharded(x, c0, mesh).labels,
                reps=3, warmup=0, reduce="min")

            rows.append({
                "leg": args.leg, "devices": m, "compression": compression,
                "iters": int(res.n_iters),
                "j": round(float(res.objective), 1),
                "wall_s": round(wall, 3),
                "s_per_sweep": round(wall / int(rt.n_iters), 5),
                "wire_bytes_per_reduction":
                    stats_wire_bytes(zero, m, compression),
            })

    with open(args.out, "w") as f:
        json.dump({"leg": args.leg, "prefetch": args.prefetch,
                   "visible_devices": len(devs),
                   "n": n, "d": d, "k": k, "chunks": chunks,
                   "batch_chunks": b, "h_star": 3e-3,
                   "timed_iters": args.timed_iters, "rows": rows}, f)
        f.write("\n")


if __name__ == "__main__":
    main()
