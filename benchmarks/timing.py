"""Shared timing methodology for the benchmark harness (ISSUE 9).

Thin re-export of :mod:`repro.kernels.timing` so the bench scripts and
the kernel autotuner time with one methodology (warmup +
``block_until_ready`` + median-of-k); the implementation lives in the
package so ``repro.kernels.autotune`` never depends on the top-level
``benchmarks`` namespace.
"""
from repro.kernels.timing import REDUCERS, time_callable

__all__ = ["REDUCERS", "time_callable"]
