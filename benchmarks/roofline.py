"""Cross-backend roofline suite (ISSUE 9).

For every (op × available backend × problem shape) cell, run the
autotuner's candidate sweep (``repro.kernels.autotune.sweep_op`` — shared
timing methodology: warmup, ``block_until_ready``, median-of-k), place
the tuned winner on the measured host roofline, and emit
``BENCH_roofline.json``::

    PYTHONPATH=src python -m benchmarks.roofline --reps 3

Per cell the row records analytic FLOPs/bytes (from the HLO cost walker
over the ``xla`` reference — backend-independent), achieved FLOP/s,
arithmetic intensity, the roofline ceiling fraction, and the
tuned-vs-default speedup.  The tracked claim is
``tuned_ge_default_every_cell``: the tuned winner is never slower than
the hand-picked default (ties allowed — the default is itself a sweep
candidate, so this holds by construction on quiet machines; CI gates it).
Backends: ``interpret`` + ``xla`` always; ``tpu``/``gpu`` join
automatically when the hardware is present.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.kernels import autotune  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_roofline.json")


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(
        prog="benchmarks.roofline",
        description="Sweep op x backend x shape cells, report roofline "
                    "placement and tuned-vs-default speedup.")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op names (default: all supported)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated backends (default: every backend "
                         "available per op on this host)")
    ap.add_argument("--shapes", default=None,
                    help="comma-separated NxKxD triples applied to every op "
                         "(default: per-op suite)")
    ap.add_argument("--reps", type=int, default=5,
                    help="timed reps per candidate (default 5)")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--out", default=OUT)
    return ap.parse_args(argv)


def _split(csv):
    return [t.strip() for t in (csv or "").split(",") if t.strip()] or None


def _shapes(csv):
    if not csv:
        return None
    return [tuple(int(p) for p in tok.strip().lower().split("x"))
            for tok in csv.split(",")]


def run(ops=None, backends=None, shapes=None, *, reps=5, warmup=1,
        timer=None, log=print):
    """Sweep the cells and return the BENCH payload dict."""
    ops = list(ops or autotune.SUPPORTED_OPS)
    peaks = autotune.measure_peaks()
    rows = []
    for op in ops:
        op_backends = [b for b in autotune.available_backends(op)
                       if backends is None or b in backends]
        for shape in (shapes or autotune.DEFAULT_SHAPES[op]):
            n, k, d = shape
            for bk in op_backends:
                sw = autotune.sweep_op(op, bk, n=n, k=k, d=d, reps=reps,
                                       warmup=warmup, timer=timer)
                tuned_s = sw["winner"]["median_s"]
                default_s = sw["default"]["median_s"]
                point = autotune.roofline_point(
                    sw["flops"], sw["bytes"], tuned_s, peaks)
                row = {
                    "op": op, "backend": bk, "n": n, "k": k, "d": d,
                    "flops": sw["flops"], "bytes": sw["bytes"],
                    "default_blocks": sw["default"]["blocks"],
                    "default_median_s": round(default_s, 6),
                    "tuned_blocks": sw["winner"]["blocks"],
                    "tuned_median_s": round(tuned_s, 6),
                    "tuned_speedup_vs_default": round(default_s / tuned_s, 4),
                    "candidates_swept": len(sw["candidates"]),
                    **{key: (round(v, 4) if isinstance(v, float) else v)
                       for key, v in point.items()},
                }
                rows.append(row)
                if log:
                    log(f"# {op}/{bk} n={n} k={k} d={d}: tuned "
                        f"{row['tuned_blocks']} {tuned_s * 1e3:.2f}ms "
                        f"({row['tuned_speedup_vs_default']:.2f}x default, "
                        f"{row['ceiling_fraction']:.1%} of roofline)")
    return {
        "benchmark": "roofline",
        "device_kind": autotune.device_kind(),
        "reps": reps,
        "warmup": warmup,
        "peaks": {key: (round(v, 3) if isinstance(v, float) else v)
                  for key, v in peaks.items()},
        "claims": {
            "tuned_ge_default_every_cell": all(
                r["tuned_speedup_vs_default"] >= 1.0 for r in rows),
        },
        "note": "achieved FLOP/s on a CPU host measure interpreter/XLA "
                "sweep throughput against the measured host roofline, not "
                "accelerator potential; ceiling_fraction > 1 is legal for "
                "cache-resident working sets (the bandwidth peak is a "
                "64MiB DRAM stream, L2/L3-resident cells beat it); the "
                "tracked claim is that the autotuned block shapes never "
                "lose to the hand-picked TilePolicy defaults (the default "
                "is a sweep candidate, so ties are the floor)",
        "rows": rows,
    }


def main(argv=None) -> int:
    args = _parse_args(argv)
    payload = run(_split(args.ops), _split(args.backends),
                  _shapes(args.shapes), reps=args.reps, warmup=args.warmup)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"# wrote {os.path.normpath(args.out)}")
    return 0 if payload["claims"]["tuned_ge_default_every_cell"] else 1


if __name__ == "__main__":
    sys.exit(main())
