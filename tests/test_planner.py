"""Planner tests (ISSUE 10): pricing edge cases, spot/on-demand crossover,
throughput interpolation off-grid, iteration-model behaviour, and the
predicted-vs-actual validation loop on the small skin config."""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import pytest

from repro.core.cost_model import (Price, PriceTable, candidate_cost_usd,
                                   expected_spot_wall_s)
from repro.core.planner import (CandidatePlan, IterationModel, PlanError,
                                PlanReport, PlanSpec, ThroughputModel,
                                ThroughputPoint, plan)

# --------------------------------------------------------------------------
# fixtures: a tiny synthetic measured grid + fitted-model stand-ins
# --------------------------------------------------------------------------


def _grid_points():
    """Seconds/iter linear in touched points (1e-6 s/pt at d=1), sharding
    overhead growing with device count — a clean, assertable surface."""
    pts = []
    for mode, frac in (("full", 1.0), ("minibatch", 0.5)):
        for dev, rate in ((1, 1.0e-6), (2, 0.6e-6), (4, 0.4e-6),
                          (8, 0.35e-6)):
            for touched in (10_000.0, 100_000.0):
                pts.append(ThroughputPoint(
                    source="test", mode=mode, backend=None,
                    compression="none", devices=dev,
                    touched_points=touched * frac,
                    s_per_iter=rate * touched * frac))
    return tuple(pts)


@pytest.fixture(scope="module")
def tp():
    return ThroughputModel(points=_grid_points())


class _FakeLM:
    """threshold_for stand-in: a dict of pinned (r* -> h*) values."""

    def __init__(self, thresholds):
        self.thresholds = thresholds

    def threshold_for(self, r):
        return self.thresholds[r]


@pytest.fixture(scope="module")
def models():
    return {"full": _FakeLM({0.99: 1e-3, 0.95: 1e-2}),
            "minibatch": _FakeLM({0.99: 1e-12, 0.95: 5e-2})}


@pytest.fixture(scope="module")
def iteration_models():
    full = IterationModel.from_traces([0.5 * 0.45 ** np.arange(14)] * 3)
    rng = np.random.default_rng(0)
    mb_h = np.maximum(0.3 * 0.9 ** np.arange(128),
                      2e-4 * (1 + 0.1 * rng.standard_normal(128)))
    mb = IterationModel.from_traces([mb_h] * 3)
    return {"full": full, "minibatch": mb}


def _spec(**kw):
    base = dict(n=100_000, d=8, k=8, target_r=0.99, deadline_s=3600.0,
                prices=PriceTable.default(), compressions=("none",))
    base.update(kw)
    return PlanSpec(**base)


# --------------------------------------------------------------------------
# price-table edge cases: loud errors naming the binding constraint
# --------------------------------------------------------------------------


def test_empty_price_table_is_loud(tp, models, iteration_models):
    with pytest.raises(PlanError, match="price table is empty"):
        plan(_spec(prices=PriceTable()), models=models,
             iteration_models=iteration_models, throughput=tp)


def test_infeasible_deadline_names_constraint(tp, models, iteration_models):
    with pytest.raises(PlanError) as e:
        plan(_spec(deadline_s=1e-9), models=models,
             iteration_models=iteration_models, throughput=tp)
    msg = str(e.value)
    # the error must name the binding constraint AND the fastest candidate
    assert "deadline" in msg
    assert "fastest" in msg
    assert "billed wall" in msg


def test_missing_mode_model_is_loud(tp, models, iteration_models):
    with pytest.raises(PlanError, match="no fitted"):
        plan(_spec(modes=("full", "minibatch", "em_mb")), models=models,
             iteration_models=iteration_models, throughput=tp)


def test_uncovered_throughput_cell_is_loud(tp):
    with pytest.raises(PlanError, match="no throughput coverage"):
        tp.seconds_per_iter(1000.0, 1, mode="full", backend="tpu")


def test_price_table_duplicate_and_lookup():
    p = Price(name="a", on_demand_per_hour=1.0)
    with pytest.raises(ValueError, match="duplicate"):
        PriceTable(prices=(p, p))
    t = PriceTable(prices=(p,))
    with pytest.raises(KeyError):
        t.get("nope")
    assert t.get("a") is p
    # JSON round trip
    t2 = PriceTable.from_json(t.to_json())
    assert t2.get("a").on_demand_per_hour == 1.0


def test_price_validation():
    with pytest.raises(ValueError):
        Price(name="bad", on_demand_per_hour=-1.0)
    with pytest.raises(ValueError):
        Price(name="bad", on_demand_per_hour=1.0, spot_per_hour=0.0)
    # spotless rows only offer on_demand
    assert Price(name="od", on_demand_per_hour=1.0).pricings == \
        ("on_demand",)


# --------------------------------------------------------------------------
# spot vs on-demand: expected-restart model + crossover monotonicity
# --------------------------------------------------------------------------


def test_spot_wall_monotone_in_preemption_rate():
    walls = [expected_spot_wall_s(600.0, lam, 4)
             for lam in (0.0, 0.05, 0.2, 1.0, 5.0)]
    assert walls[0] == 600.0                       # no preemption: exact
    assert all(b > a for a, b in zip(walls, walls[1:]))


def test_spot_wall_monotone_in_fleet_size():
    walls = [expected_spot_wall_s(600.0, 0.1, n) for n in (1, 2, 8, 32)]
    assert all(b > a for a, b in zip(walls, walls[1:]))


def test_checkpointing_caps_lost_work():
    lossy = expected_spot_wall_s(3600.0, 0.2, 4)
    ckpt = expected_spot_wall_s(3600.0, 0.2, 4, checkpoint_interval_s=60.0)
    assert ckpt < lossy


def test_spot_on_demand_crossover():
    """Cheap-but-flaky capacity must lose to on-demand once the preemption
    rate is high enough, and the crossing must be monotone: below the
    crossover spot wins everywhere, above it on-demand wins everywhere."""
    wall, n_dev = 1800.0, 4
    costs = []
    for lam in np.linspace(0.0, 20.0, 41):
        p = Price(name="x", on_demand_per_hour=1.0, spot_per_hour=0.6,
                  preemption_per_hour=float(lam))
        spot = candidate_cost_usd(wall, p, n_dev, "spot")
        od = candidate_cost_usd(wall, p, n_dev, "on_demand")
        costs.append((spot, od))
    spot_costs = [s for s, _ in costs]
    od_costs = [o for _, o in costs]
    assert all(o == od_costs[0] for o in od_costs)   # λ never touches OD
    assert all(b >= a for a, b in zip(spot_costs, spot_costs[1:]))
    wins = [s < o for s, o in costs]
    assert wins[0] and not wins[-1]                  # a crossover exists
    assert wins == sorted(wins, reverse=True)        # ... and is monotone


def test_planner_prefers_on_demand_at_high_preemption(
        tp, models, iteration_models):
    def table(lam):
        return PriceTable(prices=(Price(
            name="x", on_demand_per_hour=1.0, spot_per_hour=0.6,
            preemption_per_hour=lam),))

    calm = plan(_spec(prices=table(0.001)), models=models,
                iteration_models=iteration_models, throughput=tp)
    # restart overhead is charged per preemption event; make it dominate
    stormy = plan(_spec(prices=table(1000.0), restart_overhead_s=36000.0),
                  models=models, iteration_models=iteration_models,
                  throughput=tp)
    assert calm.chosen.pricing == "spot"
    assert stormy.chosen.pricing == "on_demand"


# --------------------------------------------------------------------------
# throughput interpolation at off-grid (N, devices)
# --------------------------------------------------------------------------


def test_devices_interpolation_off_grid(tp):
    s2 = tp.seconds_per_iter(50_000, 2, mode="full", backend=None)
    s3 = tp.seconds_per_iter(50_000, 3, mode="full", backend=None)
    s4 = tp.seconds_per_iter(50_000, 4, mode="full", backend=None)
    assert min(s2, s4) <= s3 <= max(s2, s4)
    # log2 interpolation: d=3 sits 58.5% of the way from d=2 to d=4
    t = math.log2(3) - 1
    assert s3 == pytest.approx(s2 + t * (s4 - s2), rel=1e-6)


def test_devices_clamped_beyond_grid(tp):
    s8 = tp.seconds_per_iter(50_000, 8, mode="full", backend=None)
    s16 = tp.seconds_per_iter(50_000, 16, mode="full", backend=None)
    assert s16 == pytest.approx(s8)                  # clamp, no extrapolation


def test_touched_points_interpolation_between_grid(tp):
    # measured at 10k and 100k; 55k must land linearly between them
    s10 = tp.seconds_per_iter(10_000, 1, mode="full", backend=None)
    s55 = tp.seconds_per_iter(55_000, 1, mode="full", backend=None)
    s100 = tp.seconds_per_iter(100_000, 1, mode="full", backend=None)
    assert s10 < s55 < s100
    assert s55 == pytest.approx(s10 + 0.5 * (s100 - s10), rel=1e-6)


def test_touched_points_scaling_beyond_grid(tp):
    # above the largest measurement: linear per-point rate of the top cell
    s100 = tp.seconds_per_iter(100_000, 1, mode="full", backend=None)
    s400 = tp.seconds_per_iter(400_000, 1, mode="full", backend=None)
    assert s400 == pytest.approx(4 * s100, rel=1e-6)


def test_small_n_scales_through_origin(tp):
    s10k = tp.seconds_per_iter(10_000, 1, mode="full", backend=None)
    s1k = tp.seconds_per_iter(1_000, 1, mode="full", backend=None)
    assert s1k == pytest.approx(0.1 * s10k, rel=1e-6)


def test_real_bench_files_load_and_cover_jnp_paths():
    tp_real = ThroughputModel.from_bench_dir()
    assert tp_real.points, "committed BENCH files yielded no points"
    for mode in ("full", "minibatch"):
        s1 = tp_real.seconds_per_iter(50_000, 1, mode=mode, backend=None)
        s8 = tp_real.seconds_per_iter(50_000, 8, mode=mode, backend=None)
        assert s1 > 0 and s8 > 0
    # int8_ef coverage exists for the sharded minibatch path
    s = tp_real.seconds_per_iter(50_000, 4, mode="minibatch", backend=None,
                                 compression="int8_ef")
    assert s > 0


# --------------------------------------------------------------------------
# iteration model
# --------------------------------------------------------------------------


def test_iteration_model_recovers_geometric_decay():
    h = 0.8 * 0.5 ** np.arange(20)
    im = IterationModel.from_traces([h])
    assert im.h0 == pytest.approx(0.8, rel=1e-6)
    assert im.rho == pytest.approx(0.5, rel=1e-6)
    # first i with 0.8 * 0.5^i <= 1e-3 is i = 10
    assert im.iters_to(1e-3, 400) == 10
    assert im.iters_to(1e-3, 400, patience=3) == 12


def test_iteration_model_noise_floor_predicts_max_iters():
    rng = np.random.default_rng(1)
    h = np.maximum(0.3 * 0.9 ** np.arange(200), 1e-3) \
        * (1 + 0.05 * rng.standard_normal(200))
    im = IterationModel.from_traces([h])
    assert im.h_floor > 1e-4
    assert im.iters_to(1e-12, 400) == 400       # below the floor: no stop
    assert im.iters_to(0.1, 400) < 50           # above it: geometric solve


def test_iteration_model_clamps():
    im = IterationModel.from_traces([0.5 * 0.8 ** np.arange(10)])
    assert im.iters_to(0.9, 400) == 1           # h* above h0: first iter
    assert im.iters_to(1e-30, 7) == 7           # clamped to max_iters
    assert im.n_full == 10


def test_iteration_model_empty_traces_is_loud():
    with pytest.raises(PlanError, match="no finite positive h"):
        IterationModel.from_traces([np.zeros(5), np.full(3, np.nan)])


# --------------------------------------------------------------------------
# plan() search semantics + report round trip
# --------------------------------------------------------------------------


def test_plan_noise_floor_routes_to_full_mode(tp, models, iteration_models):
    """At r*=0.99 the minibatch h* (1e-12) sits below the paired-h noise
    floor -> 400 predicted iters; full mode stops geometrically and must
    win even though its per-iteration sweeps touch 2x the points."""
    rep = plan(_spec(), models=models, iteration_models=iteration_models,
               throughput=tp)
    assert rep.chosen.mode == "full"
    mb = [c for c in rep.candidates if c.mode == "minibatch"]
    assert mb and all(c.at_noise_floor for c in mb)
    assert all(c.predicted_iters == 400 for c in mb)


def test_plan_relaxed_target_routes_to_minibatch(tp, models,
                                                 iteration_models):
    rep = plan(_spec(target_r=0.95), models=models,
               iteration_models=iteration_models, throughput=tp)
    assert rep.chosen.mode == "minibatch"
    assert not rep.chosen.at_noise_floor


def test_plan_report_is_sorted_and_priced(tp, models, iteration_models):
    rep = plan(_spec(), models=models, iteration_models=iteration_models,
               throughput=tp)
    costs = [c.predicted_cost_usd for c in rep.candidates if c.feasible]
    assert costs == sorted(costs)
    assert rep.chosen == rep.candidates[0]
    assert rep.chosen.predicted_cost_usd == pytest.approx(min(costs))
    assert 0 < rep.cost_fraction < 1
    assert rep.full_reference["iters"] == iteration_models["full"].n_full


def test_plan_deadline_filters_but_keeps_candidates(tp, models,
                                                    iteration_models):
    # at r*=0.99 the noise-floored minibatch candidates need 400 iters
    # (20s at d=1) — a 10s deadline splits the space without emptying it
    rep = plan(_spec(deadline_s=10.0), models=models,
               iteration_models=iteration_models, throughput=tp)
    slow = [c for c in rep.candidates if not c.feasible]
    assert slow, "expected some candidates to miss the 10s deadline"
    for c in slow:
        assert c.binding_constraint == "deadline_s"
    assert rep.chosen.feasible and rep.chosen.billed_wall_s <= 10.0


def test_plan_int8_gating(tp, models, iteration_models):
    rep = plan(_spec(compressions=("none", "int8_ef")), models=models,
               iteration_models=iteration_models, throughput=tp)
    for c in rep.candidates:
        if c.stats_compression == "int8_ef":
            assert c.mode == "minibatch" and c.devices >= 2


def test_plan_report_json_round_trip(tp, models, iteration_models):
    rep = plan(_spec(), models=models, iteration_models=iteration_models,
               throughput=tp)
    rep2 = PlanReport.from_json(rep.to_json())
    assert rep2.chosen == rep.chosen
    assert rep2.candidates == rep.candidates
    assert rep2.cost_fraction == pytest.approx(rep.cost_fraction)
    assert isinstance(rep2.chosen, CandidatePlan)
    # the chosen row must rebuild a real EngineConfig
    from repro.core.engine import EngineConfig
    cfg = EngineConfig(**rep2.chosen.engine_kwargs())
    assert cfg.mode == rep.chosen.mode


def test_plan_spec_validation():
    with pytest.raises(ValueError, match="target_r"):
        _spec(target_r=1.5)
    with pytest.raises(ValueError, match="deadline_s"):
        _spec(deadline_s=0.0)


def test_candidate_table_renders(tp, models, iteration_models):
    rep = plan(_spec(), models=models, iteration_models=iteration_models,
               throughput=tp)
    txt = rep.table()
    assert "<== chosen" in txt and "cost_usd" in txt


# --------------------------------------------------------------------------
# predicted vs actual on the small skin config (the real fit drivers)
# --------------------------------------------------------------------------


def test_validate_small_skin_config():
    import jax.numpy as jnp
    from repro import core
    from repro.core.planner import ThroughputModel as TM
    from repro.data import load
    from repro.launch.plan import fit_models, validate_plan

    # the harvest regime BENCH_plan.json runs (groups of 6000, chunks=16,
    # batch_chunks=4): small enough for CI, large enough that the tiny-
    # harvest h(r) fit doesn't degenerate (3000-point groups stop too
    # early and miss the accuracy target)
    k, max_iters = 2, 200
    data = load("skin", n=24_000, seed=0)
    groups = core.random_groups(data, 6_000, max_groups=3)
    models, ims = fit_models(groups[:2], algorithm="kmeans", k=k,
                             chunks=16, batch_chunks=4,
                             max_iters=max_iters, seed=0)
    prices = PriceTable.default()
    tp_real = TM.from_bench_dir()
    spec = PlanSpec(n=24_000, d=int(data.shape[1]), k=k, target_r=0.99,
                    deadline_s=3600.0, prices=prices, max_iters=max_iters,
                    chunks=16, batch_chunks=4, device_grid=(1,))
    rep = plan(spec, models=models, iteration_models=ims,
               throughput=tp_real)
    record = validate_plan(rep, jnp.asarray(groups[2], jnp.float32),
                           algorithm="kmeans", k=k, models=models,
                           throughput=tp_real, prices=prices,
                           target_r=0.99, max_iters=max_iters,
                           monitor_steps=6)
    assert record["iters_within_tolerance"], record
    assert record["actual"]["accuracy"] > 0.9, record
    assert record["straggler"]["steps"] == 6
    assert record["predicted"]["cost_usd"] > 0
    assert record["actual"]["cost_usd"] > 0
    assert record["full_actual"]["iters"] >= 1
