"""§Perf optimizations are EXACT rewrites — each must match its baseline.

1. chunkwise-parallel mLSTM  == sequential stabilised cell
2. grouped MoE dispatch      == global sort/scatter dispatch (no-drop regime)
3. ring-buffer window caches == full-length caches (gemma decode, wraparound)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm, init_lm, prefill, decode_step, init_cache
from repro.models.transformer import forward

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("chunk,s", [(8, 64), (16, 64), (32, 96), (64, 64)])
def test_chunked_mlstm_matches_sequential(chunk, s):
    cfg = get_config("xlstm-350m", reduced=True)
    p = ssm.init_mlstm(KEY, cfg)
    rng = np.random.default_rng(chunk * 100 + s)
    x = jnp.asarray(rng.normal(0, 0.5, (2, s, cfg.d_model)).astype(np.float32))
    o_ref, st_ref = ssm.mlstm(p, x, cfg, state=None)
    cfg_c = dataclasses.replace(cfg, xlstm_chunk=chunk)
    o_chk, st_chk = ssm.mlstm(p, x, cfg_c, state=None)
    assert float(jnp.max(jnp.abs(o_ref - o_chk))) < 1e-5
    for a, b in zip(st_ref, st_chk):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_chunked_mlstm_carries_state_across_chunks():
    """Chunked with an incoming state == sequential continuation."""
    cfg = get_config("xlstm-350m", reduced=True)
    p = ssm.init_mlstm(KEY, cfg)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 0.5, (1, 48, cfg.d_model)).astype(np.float32))
    _, st = ssm.mlstm(p, x[:, :16], cfg, state=None)
    o_ref, _ = ssm.mlstm(p, x[:, 16:], cfg, state=st)
    cfg_c = dataclasses.replace(cfg, xlstm_chunk=16)
    o_chk, _ = ssm.mlstm(p, x[:, 16:], cfg_c, state=st)
    assert float(jnp.max(jnp.abs(o_ref - o_chk))) < 1e-5


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_moe_matches_global(groups):
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    cfg_g = dataclasses.replace(cfg, moe_dispatch_groups=groups)
    params = init_lm(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    l1, a1 = forward(params, cfg, tokens=toks)
    l2, a2 = forward(params, cfg_g, tokens=toks)
    assert float(jnp.max(jnp.abs(l1.astype(jnp.float32)
                                 - l2.astype(jnp.float32)))) < 1e-2
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_ring_cache_decode_exact_with_wraparound():
    """window << seq: first decode after prefill must match full forward."""
    cfg = get_config("gemma3-12b", reduced=True)
    cfg = dataclasses.replace(cfg, sliding_window=8)      # ring wraps: 8 << 24
    params = init_lm(KEY, cfg)
    b, s = 2, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    logits_full, _ = forward(params, cfg, tokens=tokens)
    _, caches = prefill(params, cfg, tokens=tokens[:, :s - 1])
    full = init_cache(cfg, b, s)
    caches = jax.tree.map(
        lambda d, src: jax.lax.dynamic_update_slice(
            d, src.astype(d.dtype), (0,) * src.ndim)
        if d.shape != src.shape else src.astype(d.dtype), full, caches)
    ld, _ = decode_step(params, cfg, tokens[:, s - 1:s], caches, s - 1)
    err = float(jnp.max(jnp.abs(logits_full[:, -1].astype(jnp.float32)
                                - ld.astype(jnp.float32))))
    assert err < 1e-2, err
    # the local caches really are window-sized
    k0 = caches["pos0"]["k"]
    assert k0.shape[2] == 8


def test_ring_cache_matches_full_cache_path():
    """windowed_local_cache=False (baseline) and True agree on decode."""
    cfg_r = get_config("gemma3-12b", reduced=True)
    cfg_f = dataclasses.replace(cfg_r, windowed_local_cache=False)
    params = init_lm(KEY, cfg_r)
    b, s = 1, 20
    tokens = jax.random.randint(KEY, (b, s), 0, cfg_r.vocab)
    outs = []
    for cfg in (cfg_r, cfg_f):
        _, caches = prefill(params, cfg, tokens=tokens[:, :s - 1])
        full = init_cache(cfg, b, s)
        caches = jax.tree.map(
            lambda d, src: jax.lax.dynamic_update_slice(
                d, src.astype(d.dtype), (0,) * src.ndim)
            if d.shape != src.shape else src.astype(d.dtype), full, caches)
        ld, _ = decode_step(params, cfg, tokens[:, s - 1:s], caches, s - 1)
        outs.append(ld.astype(jnp.float32))
    assert float(jnp.max(jnp.abs(outs[0] - outs[1]))) < 1e-2
