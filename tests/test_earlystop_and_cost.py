"""LongTail controller, sampling strategy, cloud cost model (Eq. 6/9/10)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LongTailModel, EarlyStopHook, fit_longtail,
                        kfold_split, random_groups, make_grouped, report,
                        landuse_case_study)
from repro.core.cost_model import n_images_for_area, CALIFORNIA_AREA_KM2


def _model(h_at_99=1e-3):
    traces = []
    rng = np.random.default_rng(0)
    for g in range(4):
        r = rng.uniform(0.4, 1.0, 80)
        scale = h_at_99 / (1.83 * (1 - 0.99) ** 2)
        h = scale * 1.83 * (1 - r) ** 2 + rng.normal(0, 1e-6, 80)
        traces.append((r, np.abs(h)))
    return fit_longtail(traces, algorithm="kmeans", dataset="synthetic",
                        family="quadratic")


def test_longtail_json_roundtrip():
    m = _model()
    m2 = LongTailModel.from_json(m.to_json())
    assert m2.regression.coeffs == pytest.approx(m.regression.coeffs)
    assert m2.threshold_for(0.99) == pytest.approx(m.threshold_for(0.99))
    assert m2.algorithm == "kmeans" and m2.n_train_groups == 4


def test_threshold_ordering_matches_paper_table2():
    """h*(90%) > h*(95%) > h*(99%) > h*(99.9%)."""
    m = _model()
    hs = [m.threshold_for(a) for a in (0.90, 0.95, 0.99, 0.999)]
    assert hs == sorted(hs, reverse=True)
    assert hs[0] / hs[2] > 10           # orders of magnitude apart (Table 2)


def test_earlystop_hook_stops_on_plateau():
    m = _model(h_at_99=1e-3)
    hook = EarlyStopHook(m, desired_accuracy=0.99, ema=0.5, patience=3,
                         min_steps=5)
    # steeply improving → no stop; plateau → stop
    stopped_at = None
    obj = 10.0
    for step in range(200):
        obj = obj * (0.7 if step < 20 else 0.999999)
        if hook.update(obj):
            stopped_at = step
            break
    assert stopped_at is not None and stopped_at > 20


def test_earlystop_hook_respects_min_steps():
    m = _model()
    hook = EarlyStopHook(m, 0.9, min_steps=50, patience=1)
    for step in range(49):
        assert not hook.update(1.0)     # constant loss = h 0, but min_steps


@given(st.integers(10, 97), st.integers(2, 10))
@settings(max_examples=30, deadline=None)
def test_kfold_partitions(n_groups, n_folds):
    seen = []
    for f in range(n_folds):
        train, val = kfold_split(n_groups, f, n_folds, seed=1)
        assert set(train) | set(val) == set(range(n_groups))
        assert not (set(train) & set(val))
        seen.extend(val.tolist())
    assert sorted(seen) == list(range(n_groups))   # each group val exactly once


def test_random_groups_shapes_and_coverage():
    data = np.arange(1000, dtype=np.float32).reshape(-1, 1)
    g = random_groups(data, 100, seed=0)
    assert g.shape == (10, 100, 1)
    assert len(np.unique(g)) == 1000     # a partition, no duplicates


def test_grouped_pipeline():
    data = np.random.default_rng(0).normal(0, 1, (5000, 3)).astype(np.float32)
    gd = make_grouped(data, 500, fold=0, n_folds=10)
    assert gd.train_groups.shape[0] + gd.val_groups.shape[0] == 10


def test_cost_report_identities():
    r = report(time_actual_s=3600, time_full_s=7200, time_train_s=360,
               instance="m5.large")
    assert r.cost_effectiveness == pytest.approx(0.5)        # Eq. 10
    assert r.time_comp_s == 3960                             # Eq. 9
    assert r.cost_full_usd == pytest.approx(0.096 * 2)       # Eq. 6
    assert r.savings_usd == pytest.approx(0.096 * 2 - 0.096 * 1.1)


def test_landuse_case_study_scale_matches_paper():
    """§5.4: California ≈ 2.567e7 images; training cost ≈ $0.039 negligible."""
    n_img = n_images_for_area(CALIFORNIA_AREA_KM2)
    assert n_img == pytest.approx(2.567e7, rel=0.01)
    rep = landuse_case_study(time_full_per_image_s=5.0, cost_effectiveness=0.6)
    assert rep.cost_train_usd == pytest.approx(0.096 * 1169.46 / 3600,
                                               rel=1e-6)
    assert rep.cost_train_usd < 0.04
    assert rep.savings_usd > 0
    assert rep.savings_usd / rep.cost_full_usd == pytest.approx(0.4, rel=1e-3)
