"""Docs health checks (ISSUE 10 / CI `docs-check` job): no dead relative
links in docs/ or the README, and every CLI flag documented in
docs/cli.md exists in the launch module it describes."""
from __future__ import annotations

import os
import re

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = os.path.join(REPO, "docs")

DOC_FILES = sorted(
    [os.path.join(DOCS, f) for f in os.listdir(DOCS) if f.endswith(".md")]
) + [os.path.join(REPO, "README.md")]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.S)
_FLAG = re.compile(r"--[a-z][a-z0-9-]*")


def _read(path):
    with open(path) as f:
        return f.read()


def test_docs_tree_exists():
    names = {os.path.basename(p) for p in DOC_FILES}
    assert {"architecture.md", "cli.md", "cost_planning.md",
            "bench_schemas.md", "README.md"} <= names


@pytest.mark.parametrize("path", DOC_FILES,
                         ids=[os.path.relpath(p, REPO) for p in DOC_FILES])
def test_relative_links_resolve(path):
    text = _read(path)
    base = os.path.dirname(path)
    dead = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
            dead.append(target)
    assert not dead, (f"dead relative links in "
                      f"{os.path.relpath(path, REPO)}: {dead}")


def _cli_sections():
    """docs/cli.md split into (section name, body) pairs — one per
    `## <entry point>` heading."""
    text = _read(os.path.join(DOCS, "cli.md"))
    parts = text.split("\n## ")[1:]
    return [(p.split("\n", 1)[0].strip(), p) for p in parts]


def test_cli_doc_covers_every_launch_entry_point():
    documented = {name for name, _ in _cli_sections()}
    launch = os.path.join(REPO, "src", "repro", "launch")
    modules = {f[:-3] for f in os.listdir(launch)
               if f.endswith(".py") and not f.startswith("_")
               and f not in ("mesh.py", "hlo_cost.py",
                             "hlo_analysis.py")}  # libs, not CLIs
    missing = modules - documented
    assert not missing, f"launch modules undocumented in cli.md: {missing}"


@pytest.mark.parametrize("name,body", _cli_sections(),
                         ids=[n for n, _ in _cli_sections()])
def test_cli_doc_flags_exist_in_source(name, body):
    src_path = os.path.join(REPO, "src", "repro", "launch", f"{name}.py")
    assert os.path.exists(src_path), \
        f"cli.md section '{name}' has no src/repro/launch/{name}.py"
    src = _read(src_path)
    # fenced example blocks may carry env-var noise (XLA_FLAGS=...); only
    # inline-code flags are claims about the argparse surface
    prose = _FENCE.sub("", body)
    flags = set()
    for code in re.findall(r"`([^`]+)`", prose):
        flags.update(_FLAG.findall(code))
    assert flags, f"cli.md section '{name}' documents no flags"
    ghosts = [f for f in flags if f not in src]
    assert not ghosts, (f"cli.md section '{name}' documents flags missing "
                        f"from {name}.py: {sorted(ghosts)}")


def test_plan_doc_covers_all_plan_flags():
    """The reverse direction for the planner (the PR's tentpole CLI):
    every argparse flag in launch/plan.py must be documented."""
    src = _read(os.path.join(REPO, "src", "repro", "launch", "plan.py"))
    declared = set(re.findall(r"add_argument\(\s*\"(--[a-z-]+)\"", src))
    body = dict(_cli_sections())["plan"]
    documented = set(_FLAG.findall(body))
    undocumented = declared - documented
    assert not undocumented, \
        f"plan flags missing from docs/cli.md: {sorted(undocumented)}"


def test_readme_has_cost_planning_section():
    text = _read(os.path.join(REPO, "README.md"))
    assert "## Cost planning" in text
    assert "repro.launch.plan" in text
    assert "BENCH_plan.json" in text


def test_cost_planning_doc_quotes_paper_numbers():
    text = _read(os.path.join(DOCS, "cost_planning.md"))
    assert "94,687.49" in text          # the paper's US-wide saving (§5.4)
    assert "1169.46" in text            # the one-off training time (Eq. 9)


def test_bench_schema_doc_covers_committed_artifacts():
    from repro.core.planner import bench_files
    text = _read(os.path.join(DOCS, "bench_schemas.md"))
    missing = [b for b in bench_files() if b not in text]
    assert not missing, \
        f"committed BENCH artifacts undocumented in bench_schemas.md: {missing}"
