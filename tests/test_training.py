"""Training loop: learning, checkpoint/restart fault tolerance, microbatch
equivalence, compression numerics, optimizer correctness."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distribution import compression
from repro.training import (Trainer, TrainConfig, OptimizerConfig,
                            make_train_step, init_state, checkpoint as ckpt)

CFG = get_config("qwen3-8b", reduced=True)


def _data(seed=0, batch=4, seq=32):
    """Low-entropy stream (token i+1 = token i + 1 mod V) — learnable."""
    rng = np.random.default_rng(seed)
    while True:
        start = rng.integers(0, CFG.vocab, size=(batch, 1))
        ramp = (start + np.arange(seq)) % CFG.vocab
        yield {"tokens": jnp.asarray(ramp, jnp.int32)}


def test_loss_decreases_on_learnable_data():
    tc = TrainConfig(opt=OptimizerConfig(peak_lr=5e-3, warmup_steps=5,
                                         total_steps=80))
    tr = Trainer(CFG, tc, _data(), jit_step=True)
    tr.run(60)
    first = np.mean([m["loss"] for m in tr.metrics_log[:5]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-5:]])
    assert last < first * 0.5, (first, last)


def test_checkpoint_restart_bitexact():
    """Crash at step 15, restart from step-10 checkpoint → same params as an
    uninterrupted run (data iterator is restart-deterministic per step)."""
    tc = TrainConfig(opt=OptimizerConfig(peak_lr=1e-3, warmup_steps=2,
                                         total_steps=30))

    def data_from(step):
        # deterministic per-step batches so the replay after restart matches
        def gen():
            i = step
            while True:
                rng = np.random.default_rng(1000 + i)
                yield {"tokens": jnp.asarray(
                    rng.integers(0, CFG.vocab, (4, 32)), jnp.int32)}
                i += 1
        return gen()

    with tempfile.TemporaryDirectory() as d1, \
         tempfile.TemporaryDirectory() as d2:
        ref = Trainer(CFG, tc, data_from(0), ckpt_dir=d1, ckpt_every=10)
        ref.run(20)

        tr = Trainer(CFG, tc, data_from(0), ckpt_dir=d2, ckpt_every=10)
        tr.fail_at = 15
        with pytest.raises(RuntimeError, match="injected failure"):
            tr.run(20)
        tr2 = Trainer(CFG, tc, data_from(ckpt.latest_step(d2)),
                      ckpt_dir=d2, ckpt_every=10)
        assert tr2.step == 10
        tr2.run(20)

        for a, b in zip(jax.tree.leaves(ref.state.params),
                        jax.tree.leaves(tr2.state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


def test_checkpoint_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 2))}}
        for s in [10, 20, 30, 40]:
            ckpt.save(d, tree, s, keep=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert steps == ["step_00000030", "step_00000040"]
        assert ckpt.latest_step(d) == 40
        restored, step = ckpt.restore(d, tree)
        assert step == 40
        np.testing.assert_array_equal(restored["a"], np.arange(5.0))
        assert not any(x.startswith(".tmp") for x in os.listdir(d))


def test_resharding_restore(mesh8):
    """Save unsharded, restore sharded over the in-process 8-device mesh —
    the elastic-restart path, exercised against real devices (conftest
    forces the host-platform device count; no subprocess)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(32.0).reshape(8, 4)}
        ckpt.save(d, tree, 1)
        sh = {"w": NamedSharding(mesh8, P("d", None))}
        restored, _ = ckpt.restore(d, tree, shardings=sh)
        assert restored["w"].sharding == sh["w"]
        assert len(restored["w"].sharding.device_set) == 8
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(32.0).reshape(8, 4))


def test_train_step_sharded_batch_matches_replicated(mesh8):
    """One jitted train step with the batch sharded over 8 devices produces
    the same loss/params as the single-device step (pure data parallelism:
    XLA inserts the gradient all-reduce)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, CFG.vocab)}
    tc = TrainConfig()
    step = jax.jit(make_train_step(CFG, tc))
    s_ref, m_ref = step(init_state(key, CFG, tc), batch)
    sharded = {"tokens": jax.device_put(
        batch["tokens"], NamedSharding(mesh8, P("d", None)))}
    s_dp, m_dp = step(init_state(key, CFG, tc), sharded)
    assert float(m_dp["loss"]) == pytest.approx(float(m_ref["loss"]),
                                                abs=1e-4)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(s_ref.params),
                               jax.tree.leaves(s_dp.params)))
    assert diff < 1e-4


def test_microbatch_equivalence():
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, CFG.vocab)}
    tc1, tc4 = TrainConfig(microbatches=1), TrainConfig(microbatches=4)
    s1 = init_state(key, CFG, tc1)
    s4 = init_state(key, CFG, tc4)
    n1, m1 = jax.jit(make_train_step(CFG, tc1))(s1, batch)
    n4, m4 = jax.jit(make_train_step(CFG, tc4))(s4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), abs=1e-4)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(n1.params),
                               jax.tree.leaves(n4.params)))
    assert diff < 1e-4


def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 0.1, (512,)).astype(np.float32))
    q = compression.fake_quantize_grads({"g": g})["g"]
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(q - g))) <= scale * 0.5 + 1e-8


def test_error_feedback_preserves_convergence():
    """EF-SGD on a quadratic: compressed grads converge to the optimum."""
    w_star = jnp.asarray(np.random.default_rng(1).normal(0, 1, (32,)),
                         jnp.float32)
    w = jnp.zeros((32,))
    ef = {"w": jnp.zeros((32,))}
    quant_leaf = lambda x: compression.fake_quantize_grads({"_": x})["_"]
    for _ in range(300):
        g = {"w": 2 * (w - w_star)}
        gq, ef = compression.compress_with_feedback(g, ef, quant_leaf)
        w = w - 0.05 * gq["w"]
    assert float(jnp.max(jnp.abs(w - w_star))) < 1e-2


def test_compressed_training_still_learns():
    tc = TrainConfig(opt=OptimizerConfig(peak_lr=5e-3, warmup_steps=5,
                                         total_steps=80),
                     compress_grads=True)
    tr = Trainer(CFG, tc, _data(), jit_step=True)
    tr.run(50)
    first = np.mean([m["loss"] for m in tr.metrics_log[:5]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-5:]])
    assert last < first * 0.6


def test_adamw_against_reference():
    """One AdamW step vs a hand-computed reference on a tiny problem."""
    from repro.training import optimizer as opt
    # huge total_steps → cosine factor ≈ 1 at step 1, so lr == peak_lr
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=10**9,
                          weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    state = opt.init(params)
    grads = {"w": jnp.asarray([0.5, 0.5])}
    new, state2, _ = opt.apply_updates(params, grads, state, cfg)
    # step 1: m̂ = g, v̂ = g² → update = lr·g/(|g|+eps) = lr·sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               [1.0 - 0.1, -2.0 - 0.1], atol=1e-6)


def test_straggler_monitor_flags_slow_steps():
    import time
    from repro.training.straggler import StragglerMonitor
    m = StragglerMonitor(window=20, factor=2.0, grace_steps=2)
    for i in range(15):
        m.start()
        time.sleep(0.012 if i == 12 else 0.001)
        flagged = m.stop()
        if i == 12:
            assert flagged
    rep = m.report()
    assert rep["flagged"] >= 1 and rep["steps"] == 15
