"""launch.mesh: host-mesh divisibility guard (ISSUE 7 satellite — the old
builder silently floor-divided devices away) and the latency-hiding
XLA-flag toggle helpers the sharded_overlap bench spawns workers with."""
import os

import pytest

from repro.launch.mesh import (LATENCY_HIDING_FLAGS, latency_hiding_xla_flags,
                               make_host_mesh, overlap_env)


def test_make_host_mesh_rejects_non_divisor(mesh8):
    """8 visible devices, model_axis=3: a (2, 3) mesh would silently drop
    2 devices — must raise naming both numbers and the dropped count."""
    with pytest.raises(ValueError) as ei:
        make_host_mesh(model_axis=3)
    msg = str(ei.value)
    assert "model_axis=3" in msg and "8 available" in msg
    assert "drop 2" in msg


def test_make_host_mesh_rejects_nonpositive(mesh8):
    with pytest.raises(ValueError):
        make_host_mesh(model_axis=0)


def test_make_host_mesh_valid_divisors(mesh8):
    for model_axis in (1, 2, 4, 8):
        mesh = make_host_mesh(model_axis=model_axis)
        assert mesh.shape["data"] * mesh.shape["model"] == 8
        assert mesh.shape["model"] == model_axis


def test_latency_hiding_flags_append_without_duplicates():
    base = "--xla_force_host_platform_device_count=8"
    out = latency_hiding_xla_flags(base)
    parts = out.split()
    assert parts[0] == base                     # base flags survive, first
    for f in LATENCY_HIDING_FLAGS:
        assert f in parts
    # idempotent: a second application adds nothing
    again = latency_hiding_xla_flags(out)
    assert again == out
    # an explicit setting of one of the flags is respected, not duplicated
    pre = "--xla_gpu_enable_latency_hiding_scheduler=false"
    merged = latency_hiding_xla_flags(pre).split()
    names = [p.split("=", 1)[0] for p in merged]
    assert names.count("--xla_gpu_enable_latency_hiding_scheduler") == 1
    assert pre in merged


def test_overlap_env_toggles_without_mutating_environ():
    before = os.environ.get("XLA_FLAGS")
    env_on = overlap_env(enable=True)
    env_off = overlap_env(enable=False)
    assert os.environ.get("XLA_FLAGS") == before    # copies, not mutation
    for f in LATENCY_HIDING_FLAGS:
        assert f in env_on["XLA_FLAGS"].split()
    assert env_off.get("XLA_FLAGS", "") == (before or "")
