"""The graph-contract linter: every rule class must FIRE on a
deliberately-broken graph and stay quiet on the healthy engine tree.

The broken graphs reproduce the real failure classes the rules encode:
the PR 7 int8-ring deadlock (shard-divergent while trip counts over
collectives), fp64 promotion, analytic-vs-compiled wire-byte drift, and
unhashable static config fields.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import ast_rules, engine_contracts, graph_rules
from repro.analysis.report import (Finding, Report, RULE_CATALOGUE,
                                   apply_suppressions)

GOLDEN = pathlib.Path(__file__).parent / "goldens" / "lint_report.json"


@pytest.fixture(scope="module")
def data_mesh():
    if jax.device_count() < 8:
        pytest.skip(f"needs 8 devices, have {jax.device_count()}")
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _ring_perm(n):
    return [(j, (j + 1) % n) for j in range(n)]


# ------------------------------------------------------------------ GC001

def test_gc001_fires_on_divergent_while_trip_count(data_mesh):
    """A while_loop whose exit reads shard-local data while the body
    ppermutes — the PR 7 deadlock class."""
    def broken(x):
        def body(c):
            s, i = c
            y = jax.lax.ppermute(x, "data", _ring_perm(8))
            return (s + jnp.sum(y), i + 1)
        return jax.lax.while_loop(lambda c: c[0] < 100.0, body,
                                  (jnp.sum(x), jnp.int32(0)))
    fn = jax.shard_map(broken, mesh=data_mesh, in_specs=(P("data"),),
                       out_specs=(P(), P()), check_vma=False)
    jaxpr = jax.make_jaxpr(fn)(jnp.zeros((16, 3), jnp.float32))
    findings = graph_rules.check_collective_uniformity(jaxpr, "broken")
    assert len(findings) == 1 and findings[0].rule == "GC001"
    assert "shard-uniform" in findings[0].message


def test_gc001_fires_on_divergent_cond_branches(data_mesh):
    def broken(x):
        return jax.lax.cond(jax.lax.axis_index("data") < 4,
                            lambda: jax.lax.psum(jnp.sum(x), "data"),
                            lambda: jnp.sum(x))
    fn = jax.shard_map(broken, mesh=data_mesh, in_specs=(P("data"),),
                      out_specs=P(), check_vma=False)
    jaxpr = jax.make_jaxpr(fn)(jnp.zeros((16, 3), jnp.float32))
    findings = graph_rules.check_collective_uniformity(jaxpr, "broken")
    assert [f.rule for f in findings] == ["GC001"]
    assert "divergent collective sequences" in findings[0].message


def test_gc001_quiet_on_psum_gated_loop(data_mesh):
    """The engine's shape: collectives in the body, exit driven by the
    psum-reduced value — uniform, no finding."""
    def healthy(x):
        def body(c):
            tot = jax.lax.psum(jnp.sum(x) * 0.5, "data")
            return (tot, c[1] + 1)
        return jax.lax.while_loop(lambda c: (c[0] < 100.0) & (c[1] < 5),
                                  body, (jnp.float32(0), jnp.int32(0)))
    fn = jax.shard_map(healthy, mesh=data_mesh, in_specs=(P("data"),),
                       out_specs=(P(), P()), check_vma=False)
    jaxpr = jax.make_jaxpr(fn)(jnp.zeros((16, 3), jnp.float32))
    assert graph_rules.check_collective_uniformity(jaxpr, "ok") == []


def test_gc001_quiet_on_uniform_predicate_cond(data_mesh):
    """Divergent branch collectives are safe when every shard takes the
    same branch (replicated predicate)."""
    def gated(x, flag):
        return jax.lax.cond(flag > 0,
                            lambda: jax.lax.psum(jnp.sum(x), "data"),
                            lambda: jnp.sum(x))
    fn = jax.shard_map(gated, mesh=data_mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    jaxpr = jax.make_jaxpr(fn)(jnp.zeros((16, 3), jnp.float32),
                               jnp.int32(1))
    assert graph_rules.check_collective_uniformity(jaxpr, "ok") == []


# ------------------------------------------------------------------ GC002

def test_gc002_fires_on_callback_in_loop():
    def f(x):
        def step(c, xi):
            v = jax.pure_callback(
                lambda a: a, jax.ShapeDtypeStruct((), jnp.float32), xi)
            return c + v, None
        out, _ = jax.lax.scan(step, jnp.float32(0), x)
        return out
    jaxpr = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32))
    findings = graph_rules.check_host_transfers(jaxpr, "cb")
    assert findings and all(f.rule == "GC002" for f in findings)


# ------------------------------------------------------------------ GC003

def test_gc003_fires_on_fp64_graph():
    jax.config.update("jax_enable_x64", True)
    try:
        def f(x):
            return jnp.asarray(x, jnp.float64) * 2.0
        jaxpr = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32))
    finally:
        jax.config.update("jax_enable_x64", False)
    findings = graph_rules.check_fp64(jaxpr, "f64")
    assert findings and all(f.rule == "GC003" for f in findings)
    assert "float64" in findings[0].message


# ------------------------------------------------------------------ GC004

def test_gc004_fires_on_low_precision_stop_scalar():
    def f(x):
        def body(c):
            return (c[0] + jnp.bfloat16(1), c[1] + 1)
        return jax.lax.while_loop(lambda c: c[1] < 3, body,
                                  (jnp.bfloat16(0), jnp.int32(0)))
    jaxpr = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32))
    findings = graph_rules.check_stop_stats_precision(jaxpr, "prec")
    assert findings and findings[0].rule == "GC004"
    assert "bfloat16" in findings[0].message


def test_gc004_fires_on_scalar_riding_ring(data_mesh):
    def f(x):
        s = jnp.sum(x)
        return jax.lax.ppermute(s, "data", _ring_perm(8))
    fn = jax.shard_map(f, mesh=data_mesh, in_specs=(P("data"),),
                       out_specs=P(), check_vma=False)
    jaxpr = jax.make_jaxpr(fn)(jnp.zeros((16,), jnp.float32))
    findings = graph_rules.check_stop_stats_precision(jaxpr, "ring")
    assert findings and findings[0].rule == "GC004"
    assert "ring" in findings[0].message


# ------------------------------------------------------------------ GC005

def test_gc005_quiet_on_real_accounting(data_mesh):
    assert engine_contracts.check_wire_bytes(
        data_mesh, algorithms=("kmeans",)) == []


def test_gc005_fires_on_drifted_accounting(data_mesh):
    findings = engine_contracts.check_wire_bytes(
        data_mesh, algorithms=("kmeans",), compressions=("int8_ef",),
        analytic_fn=lambda stats, n, comp: 0)
    assert len(findings) == 1 and findings[0].rule == "GC005"
    assert "drifted" in findings[0].message


# ------------------------------------------------------------------ GC006

def test_gc006_fires_on_unhashable_config_field():
    from repro.core.engine import EngineConfig
    cfg = EngineConfig()
    object.__setattr__(cfg, "decay", [0.1])   # frozen bypass, on purpose
    findings = engine_contracts.check_config_static(cfg)
    assert findings and all(f.rule == "GC006" for f in findings)
    assert any("decay" in f.where for f in findings)


def test_gc006_engine_config_is_static_clean():
    assert engine_contracts.check_config_static() == []


def test_gc006_h_star_sweep_does_not_retrace(data_mesh):
    assert engine_contracts.check_h_star_traced(data_mesh) == []


# ------------------------------------------------------------------ AST

BROKEN_SRC = '''
import jax
import numpy as np

def kmeans_assign(x, centroids, *, block_n=None):
    return x

def sweep(x):
    def body(c, xi):
        return c + xi + np.random.rand(), None
    return jax.lax.scan(body, 0.0, x)

def reduce_local(x):
    return jax.lax.psum(x, "data")

def reduce_waived(x):
    return jax.lax.psum(x, "data")  # repro-lint: disable=AST002
'''


def test_ast_rules_fire_and_suppress():
    findings = ast_rules.check_source(
        BROKEN_SRC, "repro/kernels/kmeans_assign/ops.py")
    rules = sorted({f.rule for f in findings})
    assert rules == ["AST001", "AST002", "AST003"]
    flagged = [f.where for f in findings if f.rule == "AST002"]
    assert len(flagged) == 1          # the waived psum produced no finding
    assert flagged[0].endswith(":14")


BLOCK_SRC = '''
from repro.kernels.kmeans_assign.ops import kmeans_assign
from repro.kernels.layout import TilePolicy

POLICY = TilePolicy(block_rows=512, row_align=8, k_align=8, d_align=128)

def tuned(x, c):
    return kmeans_assign(x, c, block_n=256)

def waived(x, c):
    return kmeans_assign(x, c, block_n=256)  # repro-lint: disable=AST004

def resolved(x, c, bn):
    return kmeans_assign(x, c, block_n=bn)
'''


def test_ast004_flags_hardcoded_block_shapes():
    findings = ast_rules.check_source(BLOCK_SRC, "repro/somewhere.py")
    hits = [f for f in findings if f.rule == "AST004"]
    # the literal fires; the waived call, the variable-resolved call and
    # the TilePolicy constructor (the defaults themselves) stay quiet
    assert len(hits) == 1 and hits[0].where.endswith(":8"), findings
    assert "block_n=256" in hits[0].message


def test_ast001_exempt_without_x_leading_param():
    src = "def flash_attention(q, k, v, *, causal=True):\n    return q\n"
    assert ast_rules.check_source(
        src, "repro/kernels/flash_attention/ops.py") == []


def test_ast_rules_clean_on_tree():
    src_root = pathlib.Path(ast_rules.__file__).resolve().parents[1]
    assert ast_rules.check_paths(src_root) == []


# ----------------------------------------------------------- report/driver

def test_rule_catalogue_covers_all_findings():
    assert set(engine_contracts.GRAPH_RULES) <= set(RULE_CATALOGUE)
    assert {"AST001", "AST002", "AST003", "AST004"} <= set(RULE_CATALOGUE)


def test_suppression_controls_exit_decision():
    report = Report(rules_run=["GC003"])
    report.extend([Finding("GC003", "g", "fp64")])
    assert not report.ok and len(report.errors()) == 1
    apply_suppressions(report.findings, ["GC003"])
    assert report.ok and report.errors() == []
    assert report.findings[0].suppressed      # kept in the report


def _golden_report() -> Report:
    r = Report(rules_run=["GC001", "GC005"],
               configs=["kmeans|mode=full|kernel=0|comp=none|prefetch=0"])
    r.extend([
        Finding("GC001", "fit_sharded/shard_map/while",
                "while_loop exit predicate is not shard-uniform",
                config="kmeans|mode=full|kernel=0|comp=none|prefetch=0"),
        Finding("GC005", "stats_reduction[kmeans]",
                "compiled HLO moves 1792 wire bytes but the account "
                "says 448", config="kmeans|comp=int8_ef"),
    ])
    apply_suppressions(r.findings, ["GC005"])
    return r


def test_json_report_matches_golden_schema():
    """The graph-lint CI artifact's schema, pinned byte-for-byte."""
    got = json.loads(_golden_report().to_json())
    want = json.loads(GOLDEN.read_text())
    assert got == want


def test_quick_matrix_is_clean(data_mesh):
    report = engine_contracts.run_graph_lint(
        mesh=data_mesh, matrix="quick",
        rules=("GC001", "GC002", "GC003", "GC004"),
        include_restarts=False)
    assert report.ok, report.to_text()
    assert len(report.configs) == 8   # 4 cells × 2 algorithms


def test_lint_cli_json_exit_zero(tmp_path, capsys):
    from repro.launch import lint
    out = tmp_path / "report.json"
    rc = lint.main(["--rules", "GC006", "--format", "json",
                    "--out", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["schema_version"] == 1
    assert payload["summary"]["ok"] is True


def test_lint_cli_fails_then_suppresses(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text("import jax\n\ndef f(x):\n"
                   "    return jax.lax.psum(x, 'data')\n")
    from repro.launch import lint
    assert lint.main(["--rules", "AST002", "--src", str(tmp_path)]) == 1
    assert lint.main(["--rules", "AST002", "--src", str(tmp_path),
                      "--suppress", "AST002"]) == 0
