"""End-to-end behaviour of the paper's system: sample → fit regression →
early stop → accuracy/cost validation; plus the LM-loop generalisation and
the distributed clustering path (in-process 8-device session; only the CLI
smoke tests still spawn subprocesses — they test the CLI itself)."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.data import load, spacenet_pixels
from repro.launch.cluster import train_regression, run_production


@pytest.mark.parametrize("algorithm", ["kmeans", "em"])
def test_paper_pipeline_end_to_end(algorithm):
    """§4 pipeline on the skin-like dataset, k=2 (paper's Skin_Seg setup)."""
    k = 2
    data = load("skin", n=24_000, seed=0)
    groups = core.random_groups(data, 6000, max_groups=4)
    model, t_train = train_regression(groups[:3], k, algorithm,
                                      max_iters=150, family="quadratic")
    # EM's pooled (r, h) cloud on the reduced 6k-point groups is noisier
    # than k-means' (mirrors the paper, where EM's fit quality also trails);
    # 0.45 keeps the "fit is meaningful" intent without flaking on backends
    # whose fp reductions land R² within noise of 0.5.
    assert model.regression.metrics.r2 > 0.45
    h_star = model.threshold_for(0.99)
    assert h_star > 0

    val = groups[3]
    labels, _, iters, t_act = run_production(val, k, algorithm, h_star,
                                             max_iters=150, seed=9)
    labels_f, _, iters_f, t_full = run_production(
        val, k, algorithm, 0.0 if algorithm == "kmeans" else 1e-12,
        max_iters=400, seed=9)
    acc = float(core.rand_index(labels, labels_f, k, k))
    assert int(iters) <= int(iters_f)
    assert acc >= 0.95, f"{algorithm}: achieved {acc} for desired 0.99"


def test_spacenet_image_groups():
    """SpaceNet-style flow: image = sampling group (§5.2), k=6."""
    pix = spacenet_pixels(n_images=3, k_true=6, seed=0,
                          shape=(64, 64, 3))      # reduced resolution
    model, _ = train_regression(pix[:2], 6, "kmeans", max_iters=120,
                                family="quadratic")
    h_star = model.threshold_for(0.99)
    labels, _, iters, _ = run_production(pix[2], 6, "kmeans", h_star,
                                         max_iters=200)
    labels_f, _, iters_f, _ = run_production(pix[2], 6, "kmeans", 0.0,
                                             max_iters=400)
    acc = float(core.rand_index(labels, labels_f, 6, 6))
    assert acc > 0.9


def test_lm_longtail_generalisation():
    """Beyond-paper: the controller stops LM training near a target fraction
    of final quality (pilot run fits the regression, main run early-stops)."""
    from repro.configs import get_config
    from repro.training import Trainer, TrainConfig, OptimizerConfig

    cfg = get_config("qwen3-8b", reduced=True)
    tc = TrainConfig(opt=OptimizerConfig(peak_lr=5e-3, warmup_steps=5,
                                         total_steps=120))

    def data():
        rng = np.random.default_rng(7)
        while True:
            start = rng.integers(0, cfg.vocab, size=(4, 1))
            yield {"tokens": jnp.asarray((start + np.arange(32)) % cfg.vocab,
                                         jnp.int32)}

    # pilot: run to (near-)convergence, harvest (r, h) from the loss curve
    pilot = Trainer(cfg, tc, data(), seed=1)
    pilot.run(100)
    losses = np.array([m["loss"] for m in pilot.metrics_log])
    final, first = losses[-5:].mean(), losses[:3].mean()
    # quality proxy r_i = relative progress toward final loss
    sm = np.convolve(losses, np.ones(5) / 5, mode="valid")
    r = np.clip((first - sm) / max(first - final, 1e-9), 0, 1)
    h = np.abs(np.diff(sm)) / np.maximum(np.abs(sm[:-1]), 1e-9)
    model = core.fit_longtail([(r[1:], h)], algorithm="lm_train",
                              dataset="markov", family="quadratic")
    hook = core.EarlyStopHook(model, desired_accuracy=0.95, ema=0.8,
                              patience=5, min_steps=20)
    main = Trainer(cfg, tc, data(), earlystop=hook, seed=1)
    rep = main.run(100)
    if rep["stopped_early"]:
        assert rep["final_step"] < 100
        stopped_loss = main.metrics_log[-1]["loss"]
        # must have realised most of the achievable improvement
        progress = (first - stopped_loss) / max(first - final, 1e-9)
        assert progress > 0.6, progress


def test_distributed_clustering_matches_single_device(mesh8):
    """Sharded early-stopped run vs single-device run: identical stop point.
    Runs against the session's in-process 8-device view (``run_production``
    builds its own data-axis mesh from ``jax.devices()``; ``mesh8`` asserts
    the multi-device substrate is up)."""
    data = load("skin", n=16000, seed=3)
    l1, j1, i1, _ = run_production(data, 2, "kmeans", 1e-4, max_iters=100,
                                   seed=5, shard=True)
    l2, j2, i2, _ = run_production(np.asarray(data)[:l1.shape[0]], 2,
                                   "kmeans", 1e-4, max_iters=100, seed=5,
                                   shard=False)
    acc = float(core.rand_index(l1, l2, 2, 2))
    assert int(i1) == int(i2), (i1, i2)
    assert acc > 0.9999, acc


def test_distributed_minibatch_matches_single_device(mesh8):
    """--mode minibatch --shard (ISSUE 3 tentpole): the sharded chunk-draw
    path keeps every row (no truncation) and reproduces the single-device
    minibatch run — same seeded draws, same stop iteration."""
    data = load("skin", n=8192, seed=4)
    l1, j1, i1, _ = run_production(data, 2, "kmeans", 1e-3, max_iters=80,
                                   seed=5, shard=True, mode="minibatch",
                                   chunks=8, batch_chunks=2)
    l2, j2, i2, _ = run_production(data, 2, "kmeans", 1e-3, max_iters=80,
                                   seed=5, shard=False, mode="minibatch",
                                   chunks=8, batch_chunks=2)
    assert l1.shape[0] == 8192                # padded layout, not truncated
    # the chunk draws are identical; fp32 psum reduction order can still
    # flip one boundary stop step when h lands on the threshold (the strict
    # n_iters check lives in test_engine_sharded on a controlled fixture)
    assert abs(int(i1) - int(i2)) <= 1, (i1, i2)
    acc = float(core.rand_index(l1, l2, 2, 2))
    assert acc > 0.9999, acc


def test_distributed_restarts_match_unsharded(mesh8):
    """--restarts 4 --shard (ISSUE 3): the vmap-inside-shard_map fleet
    agrees with the unsharded vmapped fleet on the best objective."""
    data = load("skin", n=8192, seed=6)
    l1, j1, i1, _ = run_production(data, 2, "kmeans", 1e-4, max_iters=60,
                                   seed=5, shard=True, restarts=4)
    l2, j2, i2, _ = run_production(data, 2, "kmeans", 1e-4, max_iters=60,
                                   seed=5, shard=False, restarts=4)
    assert abs(int(i1) - int(i2)) <= 1, (i1, i2)   # see minibatch test above
    np.testing.assert_allclose(j1, j2, rtol=1e-5)
    acc = float(core.rand_index(l1, l2, 2, 2))
    assert acc > 0.9999, acc


def test_shard_fallback_helper_is_loud(capsys):
    """--shard on a 1-device host must announce the fallback, not silently
    run replicated while the user believes the distributed path ran."""
    from repro.launch.cluster import _resolve_shard
    assert _resolve_shard(True, 1) is False
    out = capsys.readouterr().out
    assert "--shard" in out and "only 1 device" in out
    assert "xla_force_host_platform_device_count" in out   # the fix hint
    assert _resolve_shard(True, 8) is True
    assert _resolve_shard(False, 1) is False
    assert capsys.readouterr().out == ""                   # quiet otherwise


@pytest.mark.skipif(jax.device_count() != 1,
                    reason="exercises the forced-1-device CI leg")
def test_shard_single_device_end_to_end_warns(capsys):
    """On the 1-device CI leg the whole production path must still work
    under --shard, with the explicit fallback message."""
    data = load("skin", n=2000, seed=0)
    labels, _, _, _ = run_production(data, 2, "kmeans", 1e-3, max_iters=30,
                                     seed=1, shard=True)
    assert labels.shape[0] == 2000
    assert "only 1 device" in capsys.readouterr().out


def _cli_env():
    """Stock environment for CLI smokes: undo conftest's session-wide
    8-device flag so the CLI is exercised the way a user runs it."""
    import os
    import conftest
    return {**os.environ, "PYTHONPATH": "src",
            "XLA_FLAGS": conftest.ORIG_XLA_FLAGS}


def test_cluster_cli_smoke(tmp_path):
    out = tmp_path / "rep.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.cluster", "--dataset", "skin",
         "--k", "2", "--n", "12000", "--group-size", "3000",
         "--train-groups", "2", "--desired-accuracy", "0.99",
         "--out", str(out)],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env=_cli_env())
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rep = json.loads(out.read_text())
    assert rep["achieved_accuracy"] > 0.9
    assert rep["iters_earlystop"] <= rep["iters_full"]


def test_cluster_save_artifact_serves(tmp_path):
    """ISSUE 7 satellite, fit → save → serve: the cluster CLI's
    --save-artifact JSON must round-trip through serve_cluster --registry
    (the registry layout the assignment server consumes)."""
    from repro.core import ClusterArtifact
    registry = tmp_path / "registry"
    registry.mkdir()
    art_path = registry / "skin-kmeans-k2.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.cluster", "--dataset", "skin",
         "--k", "2", "--n", "9000", "--group-size", "3000",
         "--train-groups", "2", "--prod-groups", "1", "--max-iters", "60",
         "--save-artifact", str(art_path)],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env=_cli_env())
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    art = ClusterArtifact.load(str(art_path))     # well-formed on disk
    assert art.algorithm == "kmeans" and art.k == 2 and art.d == 4
    assert art.model.threshold_for(0.99) > 0      # stop-model rides along

    out = tmp_path / "serve.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_cluster",
         "--registry", str(registry), "--requests", "8",
         "--out", str(out)],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env=_cli_env())
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rep = json.loads(out.read_text())
    assert rep["n_results"] == 8


def test_run_production_return_params_opt_in():
    """The 4-tuple contract at every existing call site stays; the 5th
    element appears only on request, on each of the three return paths."""
    data = load("skin", n=2000, seed=1)
    out = run_production(data, 2, "kmeans", 1e-3, max_iters=20, seed=1)
    assert len(out) == 4
    for kw in (dict(), dict(restarts=2)):
        out = run_production(data, 2, "kmeans", 1e-3, max_iters=20, seed=1,
                             return_params=True, **kw)
        assert len(out) == 5
        assert np.shape(out[4]) == (2, 4)         # centroids [K, D]


def test_run_production_compression_guards():
    """stats_compression must not silently corrupt the frozen-stop
    full-convergence reference (h*=0 kmeans baseline)."""
    data = load("skin", n=2000, seed=1)
    with pytest.raises(ValueError, match="full-convergence"):
        run_production(data, 2, "kmeans", 0.0, max_iters=20,
                       stats_compression="int8_ef")


def test_sharded_compressed_production(mesh8):
    """--shard --stats-compression int8_ef end-to-end: the compressed run
    stops within a boundary iteration of the fp32 psum run and agrees on
    the partition."""
    data = load("skin", n=8192, seed=4)
    kw = dict(max_iters=80, seed=5, shard=True, mode="minibatch",
              chunks=8, batch_chunks=2)
    l1, j1, i1, _ = run_production(data, 2, "kmeans", 1e-3, **kw)
    l2, j2, i2, _ = run_production(data, 2, "kmeans", 1e-3,
                                   stats_compression="int8_ef",
                                   prefetch=True, **kw)
    assert abs(int(i1) - int(i2)) <= 1, (i1, i2)
    assert float(core.rand_index(l1, l2, 2, 2)) > 0.999


def test_train_cli_smoke(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
         "--steps", "8", "--batch", "2", "--seq", "32",
         "--ckpt-dir", str(tmp_path / "ck"),
         "--out", str(tmp_path / "train.json")],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env=_cli_env())
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rep = json.loads((tmp_path / "train.json").read_text())
    assert rep["final_step"] == 8
