"""Property-based early-stop invariants (ISSUE 1): objective monotonicity,
change-rate scale invariance, LongTailModel persistence round-trip; plus
streamed k-means++ invariants (ISSUE 2): k distinct in-bounds picks under
any chunking, and exact chunks=1 equivalence with the monolithic pass.

Runs under real hypothesis when installed, or under the seeded
mini-hypothesis shim in conftest.py on a bare JAX install.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import core
from repro.core import em_gmm
from repro.core.earlystop import change_rate


def _blobs(seed: int, n: int, k: int, d: int = 3):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 6.0, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.0, (n // k, d)) for c in centers])
    return jnp.asarray(x.astype(np.float32))


@given(seed=st.integers(0, 10_000), k=st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_kmeans_objective_monotone_nonincreasing(seed, k):
    x = _blobs(seed, 240, k)
    c0 = core.random_init(jax.random.PRNGKey(seed), x, k)
    res = core.kmeans_fit_traced(x, c0, max_iters=40)
    js = np.asarray(res["objectives"], np.float64)
    rel = np.diff(js) / np.maximum(np.abs(js[:-1]), 1e-9)
    assert rel.max() <= 1e-5, \
        f"k-means J increased by {rel.max():.2e} (seed={seed}, k={k})"


@given(seed=st.integers(0, 10_000), k=st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_em_loglik_monotone_nondecreasing(seed, k):
    x = _blobs(seed, 240, k)
    p0 = em_gmm.random_init(jax.random.PRNGKey(seed), x, k)
    res = em_gmm.em_fit_traced(x, p0, max_iters=30, tol=1e-12)
    js = np.asarray(res["objectives"], np.float64)
    rel = np.diff(js) / np.maximum(np.abs(js[:-1]), 1e-9)
    assert rel.min() >= -1e-5, \
        f"EM loglik decreased by {rel.min():.2e} (seed={seed}, k={k})"


@given(alpha=st.floats(1e-3, 1e3),
       j_prev=st.one_of(st.floats(-500.0, -0.5), st.floats(0.5, 500.0)),
       delta=st.floats(-10.0, 10.0))
@settings(max_examples=25, deadline=None)
def test_change_rate_scale_invariant(alpha, j_prev, delta):
    """h(αJ_i, αJ_{i-1}) == h(J_i, J_{i-1}): Eq. 7 is a *relative* rate, so
    the fitted h* transfers across objective scales (dataset sizes).
    Checked in f64 — in f32 the subtraction's cancellation noise would
    drown the property itself."""
    from jax.experimental import enable_x64
    j_curr = j_prev + delta
    with enable_x64():
        h1 = float(change_rate(jnp.float64(j_curr), jnp.float64(j_prev)))
        h2 = float(change_rate(jnp.float64(alpha * j_curr),
                               jnp.float64(alpha * j_prev)))
    assert h2 == pytest.approx(h1, rel=1e-9, abs=1e-15)


def _monolithic_kmeans_pp(key, x, k):
    """The historical flat k-means++ pass (resident [N] d², resident [N, D]
    difference temporaries) with the engine's key schedule: the reference
    the streamed implementation must reproduce bit-for-bit at chunks=1."""
    x = x.astype(jnp.float32)
    n = x.shape[0]
    key, sub = jax.random.split(key)
    first = x[jax.random.randint(sub, (), 0, n)]
    cent = [first]
    d2 = jnp.sum((x - first) ** 2, axis=-1)
    for _ in range(1, k):
        key, sub = jax.random.split(key)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-30)
        c = x[jax.random.choice(sub, n, p=probs)]
        cent.append(c)
        d2 = jnp.minimum(d2, jnp.sum((x - c) ** 2, axis=-1))
    return jnp.stack(cent)


@given(seed=st.integers(0, 10_000), k=st.integers(2, 6),
       n=st.integers(50, 400), chunks=st.integers(1, 13))
@settings(max_examples=12, deadline=None)
def test_streamed_kmeanspp_picks_k_distinct_inbounds_points(seed, k, n,
                                                            chunks):
    """For ANY chunking (dividing n or not, more chunks than needed or not)
    the streamed D² sampler returns k distinct rows of x — never a padding
    row, never a repeat (chosen points carry exactly zero d² mass)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 5.0, (n, 3)).astype(np.float32))
    c = core.kmeans_plus_plus_init(jax.random.PRNGKey(seed), x, k,
                                   chunks=chunks)
    got = np.asarray(c)
    rows = {tuple(r) for r in np.asarray(x)}
    assert all(tuple(r) in rows for r in got), "picked a non-data point"
    assert len({tuple(r) for r in got}) == k, "picked a duplicate"


@given(seed=st.integers(0, 10_000), k=st.integers(2, 6))
@settings(max_examples=12, deadline=None)
def test_streamed_kmeanspp_chunks1_equals_monolithic_exactly(seed, k):
    """chunks=1 must reduce the scan machinery to the flat pass bit-for-bit
    (same key schedule, same draws, same arithmetic) — the guard that lets
    every existing seed keep its value."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 5.0, (257, 4)).astype(np.float32))
    key = jax.random.PRNGKey(seed)
    a = _monolithic_kmeans_pp(key, x, k)
    b = core.kmeans_plus_plus_init(key, x, k, chunks=1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(seed=st.integers(0, 99), a=st.floats(0.5, 3.0))
@settings(max_examples=10, deadline=None)
def test_longtail_model_json_roundtrip(seed, a):
    rng = np.random.default_rng(seed)
    r = rng.uniform(0.3, 1.0, 80)
    h = a * (1.0 - r) ** 2 * (1 + rng.normal(0, 0.02, r.size))
    m = core.fit_longtail([(r, np.abs(h))], algorithm="kmeans",
                          dataset=f"synthetic-{seed}", family="quadratic")
    m2 = core.LongTailModel.from_json(m.to_json())
    assert m2.algorithm == m.algorithm
    assert m2.dataset == m.dataset
    assert m2.n_train_groups == m.n_train_groups
    assert m2.regression.family == m.regression.family
    np.testing.assert_allclose(m2.regression.coeffs, m.regression.coeffs,
                               rtol=1e-12)
    for acc in (0.9, 0.95, 0.99):
        assert m2.threshold_for(acc) == pytest.approx(m.threshold_for(acc))


def test_longtail_roundtrip_with_comparison_table():
    """family=None stores the model-selection table; it must survive JSON."""
    rng = np.random.default_rng(0)
    r = rng.uniform(0.2, 1.0, 200)
    h = 1.8 * (1 - r) ** 2 + np.abs(rng.normal(0, 1e-3, r.size))
    m = core.fit_longtail([(r, h)], algorithm="em", dataset="synthetic",
                          family=None)
    m2 = core.LongTailModel.from_json(m.to_json())
    assert m2.comparison is not None
    assert set(m2.comparison) == set(m.comparison)
    assert m2.threshold_for(0.99) == pytest.approx(m.threshold_for(0.99))
