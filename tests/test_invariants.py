"""Property-based early-stop invariants (ISSUE 1): objective monotonicity,
change-rate scale invariance, LongTailModel persistence round-trip.

Runs under real hypothesis when installed, or under the seeded
mini-hypothesis shim in conftest.py on a bare JAX install.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import core
from repro.core import em_gmm
from repro.core.earlystop import change_rate


def _blobs(seed: int, n: int, k: int, d: int = 3):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 6.0, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.0, (n // k, d)) for c in centers])
    return jnp.asarray(x.astype(np.float32))


@given(seed=st.integers(0, 10_000), k=st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_kmeans_objective_monotone_nonincreasing(seed, k):
    x = _blobs(seed, 240, k)
    c0 = core.random_init(jax.random.PRNGKey(seed), x, k)
    res = core.kmeans_fit_traced(x, c0, max_iters=40)
    js = np.asarray(res["objectives"], np.float64)
    rel = np.diff(js) / np.maximum(np.abs(js[:-1]), 1e-9)
    assert rel.max() <= 1e-5, \
        f"k-means J increased by {rel.max():.2e} (seed={seed}, k={k})"


@given(seed=st.integers(0, 10_000), k=st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_em_loglik_monotone_nondecreasing(seed, k):
    x = _blobs(seed, 240, k)
    p0 = em_gmm.random_init(jax.random.PRNGKey(seed), x, k)
    res = em_gmm.em_fit_traced(x, p0, max_iters=30, tol=1e-12)
    js = np.asarray(res["objectives"], np.float64)
    rel = np.diff(js) / np.maximum(np.abs(js[:-1]), 1e-9)
    assert rel.min() >= -1e-5, \
        f"EM loglik decreased by {rel.min():.2e} (seed={seed}, k={k})"


@given(alpha=st.floats(1e-3, 1e3),
       j_prev=st.one_of(st.floats(-500.0, -0.5), st.floats(0.5, 500.0)),
       delta=st.floats(-10.0, 10.0))
@settings(max_examples=25, deadline=None)
def test_change_rate_scale_invariant(alpha, j_prev, delta):
    """h(αJ_i, αJ_{i-1}) == h(J_i, J_{i-1}): Eq. 7 is a *relative* rate, so
    the fitted h* transfers across objective scales (dataset sizes).
    Checked in f64 — in f32 the subtraction's cancellation noise would
    drown the property itself."""
    from jax.experimental import enable_x64
    j_curr = j_prev + delta
    with enable_x64():
        h1 = float(change_rate(jnp.float64(j_curr), jnp.float64(j_prev)))
        h2 = float(change_rate(jnp.float64(alpha * j_curr),
                               jnp.float64(alpha * j_prev)))
    assert h2 == pytest.approx(h1, rel=1e-9, abs=1e-15)


@given(seed=st.integers(0, 99), a=st.floats(0.5, 3.0))
@settings(max_examples=10, deadline=None)
def test_longtail_model_json_roundtrip(seed, a):
    rng = np.random.default_rng(seed)
    r = rng.uniform(0.3, 1.0, 80)
    h = a * (1.0 - r) ** 2 * (1 + rng.normal(0, 0.02, r.size))
    m = core.fit_longtail([(r, np.abs(h))], algorithm="kmeans",
                          dataset=f"synthetic-{seed}", family="quadratic")
    m2 = core.LongTailModel.from_json(m.to_json())
    assert m2.algorithm == m.algorithm
    assert m2.dataset == m.dataset
    assert m2.n_train_groups == m.n_train_groups
    assert m2.regression.family == m.regression.family
    np.testing.assert_allclose(m2.regression.coeffs, m.regression.coeffs,
                               rtol=1e-12)
    for acc in (0.9, 0.95, 0.99):
        assert m2.threshold_for(acc) == pytest.approx(m.threshold_for(acc))


def test_longtail_roundtrip_with_comparison_table():
    """family=None stores the model-selection table; it must survive JSON."""
    rng = np.random.default_rng(0)
    r = rng.uniform(0.2, 1.0, 200)
    h = 1.8 * (1 - r) ** 2 + np.abs(rng.normal(0, 1e-3, r.size))
    m = core.fit_longtail([(r, h)], algorithm="em", dataset="synthetic",
                          family=None)
    m2 = core.LongTailModel.from_json(m.to_json())
    assert m2.comparison is not None
    assert set(m2.comparison) == set(m.comparison)
    assert m2.threshold_for(0.99) == pytest.approx(m.threshold_for(0.99))
