"""Kernel autotuner (ISSUE 9): versioned winner cache round-trip and
schema gates, scoped activation and the ops' resolution order (explicit
override > active cache > TilePolicy default), cache-hit short-circuit,
deterministic winner selection under a scripted clock, the shared timing
methodology, the ``bucket_for`` round-up contract above the ladder, and
tuned-vs-untuned engine stop-iteration parity across mode × backend."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.engine import ClusteringEngine, EngineConfig
from repro.kernels import autotune, dispatch, layout
from repro.kernels.kmeans_assign.ops import kmeans_assign
from repro.kernels.timing import REDUCERS, time_callable


class ScriptedTimer:
    """Deterministic clock: each timed rep elapses the next scripted
    duration (time_callable brackets fn with exactly two clock calls)."""

    def __init__(self, durations):
        self.durations = list(durations)
        self.t = 0.0
        self._open = False
        self.calls = 0

    def __call__(self):
        self.calls += 1
        if not self._open:
            self._open = True
            return self.t
        self._open = False
        self.t += self.durations.pop(0)
        return self.t


# --------------------------------------------------------------------------
# Cache: round trip, schema version, malformed payloads
# --------------------------------------------------------------------------

def test_cache_json_round_trip(tmp_path):
    cache = autotune.AutotuneCache()
    cache.put("kmeans_assign", "interpret", n=4096, k=8, d=16,
              blocks={"block_n": 512}, median_s=0.001)
    cache.put("flash_attention", "interpret", n=512, k=512, d=64,
              blocks={"block_q": 64, "block_k": 128})
    path = tmp_path / "cache.json"
    cache.save(str(path))
    loaded = autotune.AutotuneCache.load(str(path))
    assert loaded.entries == cache.entries
    assert loaded.lookup("kmeans_assign", "interpret",
                         n=4096, k=8, d=16) == {"block_n": 512}
    assert loaded.lookup("flash_attention", "interpret", n=512, k=512,
                         d=64) == {"block_q": 64, "block_k": 128}
    # the n key is bucketed: any n padding to the same bucket hits
    assert loaded.lookup("kmeans_assign", "interpret",
                         n=2000, k=8, d=16) == {"block_n": 512}
    # a different (k, d) is a different cell
    assert loaded.lookup("kmeans_assign", "interpret",
                         n=4096, k=8, d=8) is None


def test_cache_rejects_stale_schema_version(tmp_path):
    path = tmp_path / "stale.json"
    path.write_text(json.dumps({"schema_version": 0, "entries": {}}))
    with pytest.raises(autotune.StaleCacheError, match="schema_version=0"):
        autotune.AutotuneCache.load(str(path))


def test_cache_rejects_malformed_payloads():
    with pytest.raises(ValueError, match="no 'entries'"):
        autotune.AutotuneCache.from_payload(
            {"schema_version": autotune.SCHEMA_VERSION, "entries": []})
    for blocks in ({"block_n": 0}, {"block_n": "512"}, None):
        with pytest.raises(ValueError, match="malformed"):
            autotune.AutotuneCache.from_payload({
                "schema_version": autotune.SCHEMA_VERSION,
                "entries": {"cell": {"blocks": blocks}}})


# --------------------------------------------------------------------------
# Scoped activation + resolution order at the op call sites
# --------------------------------------------------------------------------

def _cache_with(op, backend, *, n, k, d, blocks):
    cache = autotune.AutotuneCache()
    cache.put(op, backend, n=n, k=k, d=d, blocks=blocks)
    return cache


def test_tuned_blocks_needs_an_active_scope():
    cache = _cache_with("kmeans_assign", "interpret", n=4096, k=8, d=16,
                        blocks={"block_n": 256})
    assert autotune.tuned_blocks("kmeans_assign", "interpret",
                                 n=4096, k=8, d=16) is None
    with autotune.tuning(cache):
        assert autotune.tuned_blocks(
            "kmeans_assign", "interpret",
            n=4096, k=8, d=16) == {"block_n": 256}
        # no entry for this backend → None (defaults apply)
        assert autotune.tuned_blocks("kmeans_assign", "xla",
                                     n=4096, k=8, d=16) is None
    assert autotune.tuned_blocks("kmeans_assign", "interpret",
                                 n=4096, k=8, d=16) is None


def test_cache_from_other_device_kind_never_matches():
    cache = autotune.AutotuneCache()
    key = autotune.AutotuneCache.key("kmeans_assign", "interpret",
                                     n=4096, k=8, d=16, kind="TPU_v4")
    cache.entries[key] = {"blocks": {"block_n": 256}}
    with autotune.tuning(cache):
        assert autotune.tuned_blocks("kmeans_assign", "interpret",
                                     n=4096, k=8, d=16) is None


def test_resolution_order_at_the_op_call_site():
    """explicit block_n > active cache > TilePolicy default, observed
    through a fake registered backend that records the resolved block."""
    seen = []

    @dispatch.register_backend("kmeans_assign", "spybk")
    def _spy(x, w, c, *, block_n):
        seen.append(block_n)
        n, d = x.shape
        k = c.shape[0]
        return (jnp.zeros((n,), jnp.int32), jnp.zeros((k, d)),
                jnp.zeros((k,)), jnp.zeros(()))

    x = jnp.zeros((4096, 16), jnp.float32)
    c = jnp.zeros((8, 16), jnp.float32)
    pol = layout.tile_policy("spybk")
    cache = _cache_with("kmeans_assign", "spybk", n=4096, k=8, d=16,
                        blocks={"block_n": 256})
    try:
        kmeans_assign(x, c, backend="spybk")
        assert seen[-1] == pol.block_for(4096)           # untuned default
        with autotune.tuning(cache):
            kmeans_assign(x, c, backend="spybk")
            assert seen[-1] == 256                       # cache consulted
            kmeans_assign(x, c, backend="spybk", block_n=512)
            assert seen[-1] == 512                       # override wins
    finally:
        dispatch.get_op("kmeans_assign")._impls.pop("spybk")


# --------------------------------------------------------------------------
# Sweep + tune: determinism, short-circuit, winner ≥ default by construction
# --------------------------------------------------------------------------

def test_sweep_winner_is_deterministic_under_scripted_clock():
    cands = autotune.candidate_blocks("kmeans_assign", "interpret",
                                      n=4096, k=8, d=16)
    assert len(cands) > 2 and cands[0] == {"block_n": 1024}  # default first
    # candidate at index 2 gets the smallest duration → must win, twice
    durations = [3.0, 2.0, 1.0, 4.0, 5.0][:len(cands)]
    for _ in range(2):
        sw = autotune.sweep_op(
            "kmeans_assign", "interpret", n=4096, k=8, d=16,
            reps=1, warmup=0, timer=ScriptedTimer(durations),
            call_factory=lambda blocks: (lambda: None), include_cost=False)
        assert sw["winner"]["blocks"] == cands[2]
        assert sw["default"]["blocks"] == cands[0]
        assert sw["default"]["median_s"] >= sw["winner"]["median_s"]


def test_winner_ties_resolve_to_the_default():
    cands = autotune.candidate_blocks("kmeans_assign", "interpret",
                                      n=4096, k=8, d=16)
    sw = autotune.sweep_op(
        "kmeans_assign", "interpret", n=4096, k=8, d=16,
        reps=1, warmup=0, timer=ScriptedTimer([1.0] * len(cands)),
        call_factory=lambda blocks: (lambda: None), include_cost=False)
    assert sw["winner"]["blocks"] == cands[0]  # argmin is first on ties


def test_tune_cache_hit_short_circuits_retiming():
    shapes = [(64, 4, 4)]
    timer = ScriptedTimer([1.0] * 64)
    cache = autotune.tune(
        ops=["kmeans_assign"], backends=["interpret"], shapes=shapes,
        reps=1, warmup=0, timer=timer, include_cost=False,
        call_factory=lambda blocks: (lambda: None))
    assert cache.lookup("kmeans_assign", "interpret", n=64, k=4, d=4)
    first_calls = timer.calls
    assert first_calls > 0
    # same cells, same cache → no candidate is ever re-timed
    autotune.tune(
        ops=["kmeans_assign"], backends=["interpret"], shapes=shapes,
        reps=1, warmup=0, timer=timer, include_cost=False, cache=cache,
        call_factory=lambda blocks: (lambda: None))
    assert timer.calls == first_calls


def test_candidate_grids_respect_backend_policy():
    # xla ignores blocks entirely → a sweep would time one program N ways
    assert autotune.candidate_blocks("kmeans_assign", "xla",
                                     n=4096, k=8, d=16) == \
        [{"block_n": 1024}]
    # gpu (Triton): every candidate must satisfy the pow2 rule
    for cand in autotune.candidate_blocks("kmeans_assign", "gpu",
                                          n=4096, k=8, d=16):
        bn = cand["block_n"]
        assert bn & (bn - 1) == 0, cand
    # flash: pairs capped to the aligned sequence lengths, default first
    fl = autotune.candidate_blocks("flash_attention", "interpret",
                                   n=128, k=512, d=64)
    assert fl[0] == {"block_q": 128, "block_k": 128}
    assert all(c["block_q"] <= 128 for c in fl)


def test_roofline_point_geometry():
    peaks = {"flops_per_s": 1e12, "bytes_per_s": 1e10}
    low = autotune.roofline_point(1e9, 1e9, 1e-3, peaks)   # intensity 1
    assert low["bound"] == "memory"
    assert low["roofline_ceiling_flops_per_s"] == pytest.approx(1e10)
    assert low["achieved_flops_per_s"] == pytest.approx(1e12)
    high = autotune.roofline_point(1e12, 1e9, 1.0, peaks)  # intensity 1e3
    assert high["bound"] == "compute"
    assert high["roofline_ceiling_flops_per_s"] == pytest.approx(1e12)
    assert high["ceiling_fraction"] == pytest.approx(1.0)


# --------------------------------------------------------------------------
# Shared timing methodology
# --------------------------------------------------------------------------

def test_time_callable_reducers_with_scripted_clock():
    samples = [3.0, 1.0, 2.0]
    for reduce, want in (("median", 2.0), ("min", 1.0), ("mean", 2.0)):
        t = time_callable(lambda: None, reps=3, warmup=0, reduce=reduce,
                          timer=ScriptedTimer(samples))
        assert t == pytest.approx(want), reduce
    assert set(REDUCERS) == {"median", "min", "mean"}


def test_time_callable_validates_arguments():
    with pytest.raises(ValueError, match="reduce"):
        time_callable(lambda: None, reduce="p99")
    with pytest.raises(ValueError, match="reps"):
        time_callable(lambda: None, reps=0)


def test_time_callable_warmup_is_untimed():
    calls = []
    timer = ScriptedTimer([1.0, 1.0])
    time_callable(lambda: calls.append(1), reps=2, warmup=3, timer=timer)
    assert len(calls) == 5                   # 3 warmup + 2 timed
    assert timer.calls == 4                  # clock brackets timed reps only


# --------------------------------------------------------------------------
# bucket_for: the ISSUE 9 round-up contract above the ladder
# --------------------------------------------------------------------------

def test_bucket_for_boundary_regression():
    top = layout.DEFAULT_BUCKETS[-1]
    assert layout.bucket_for(1) == layout.DEFAULT_BUCKETS[0]
    assert layout.bucket_for(top) == top          # exact top: in-ladder
    assert layout.bucket_for(top + 1) == 2 * top  # just above: rounds up
    assert layout.bucket_for(3 * top - 1) == 3 * top
    assert layout.bucket_for(3 * top) == 3 * top  # policy-aligned multiple


def test_bucket_for_impossible_padding_fails_loud():
    with pytest.raises(ValueError, match="cannot pad"):
        layout.bucket_for(0)
    with pytest.raises(ValueError, match="non-empty bucket ladder"):
        layout.bucket_for(100, buckets=())


# --------------------------------------------------------------------------
# Engine integration: autotuned fits reproduce untuned stop iterations
# --------------------------------------------------------------------------

def test_engine_config_autotune_requires_kernel_path():
    with pytest.raises(ValueError, match="use_kernel"):
        EngineConfig(autotune=True)
    EngineConfig(autotune=True, use_kernel=True)   # valid combination


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(7)
    k, d, n = 8, 8, 2048
    centers = rng.normal(0, 6.0, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.5, (n // k, d))
                        for c in centers])
    x = jnp.asarray(x[rng.permutation(n)].astype(np.float32))
    return x, core.random_init(jax.random.PRNGKey(0), x, k)


@pytest.fixture()
def pinned_cache():
    """A process-default cache pinning a NON-default block_n for every
    bucket the engine fits below can hit, on both CI backends."""
    cache = autotune.AutotuneCache()
    for backend in ("interpret", "xla"):
        for n in (256, 1024, 4096):
            cache.put("kmeans_assign", backend, n=n, k=8, d=8,
                      blocks={"block_n": 256})
    autotune.set_default_cache(cache)
    try:
        yield cache
    finally:
        autotune.set_default_cache(None)
        jax.clear_caches()   # drop traces that baked in the pinned blocks


@pytest.mark.parametrize("mode", ["full", "minibatch"])
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_autotuned_fit_matches_untuned_stop_exactly(blobs, pinned_cache,
                                                    mode, backend):
    # h* = 3e-3 crosses while h is in steep decay, so the stop margin
    # dwarfs the fp32 reduction-order noise a different block_n regroups
    # (the PR 7 parity-threshold precedent)
    x, c0 = blobs
    kw = dict(max_iters=60, use_kernel=True, kernel_backend=backend, seed=0)
    if mode == "minibatch":
        kw.update(mode="minibatch", chunks=4, batch_chunks=2, patience=3,
                  decay=0.95)
    base = ClusteringEngine("kmeans", EngineConfig(**kw)).fit(
        x, c0, h_star=3e-3)
    tuned = ClusteringEngine("kmeans", EngineConfig(autotune=True, **kw)) \
        .fit(x, c0, h_star=3e-3)
    assert int(base.n_iters) == int(tuned.n_iters), (mode, backend)
    # a different block_n regroups fp32 accumulation, so the objectives
    # agree to reduction-order noise, not bit-for-bit
    assert float(tuned.objective) == pytest.approx(
        float(base.objective), rel=1e-4)


def test_default_cache_env_lookup(tmp_path, monkeypatch):
    cache = _cache_with("kmeans_assign", "interpret", n=4096, k=8, d=16,
                        blocks={"block_n": 512})
    path = tmp_path / "env_cache.json"
    cache.save(str(path))
    autotune.set_default_cache(None)
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    try:
        loaded = autotune.default_cache()
        assert loaded is not None and loaded.lookup(
            "kmeans_assign", "interpret", n=4096, k=8, d=16) == \
            {"block_n": 512}
    finally:
        autotune.set_default_cache(None)
