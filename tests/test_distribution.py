"""Sharding rules + int8 ring all-reduce (in-process 8-device mesh)."""
import functools
import re

import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs, SHAPES
from repro.models import transformer, model_zoo


def _mesh_proxy():
    """A (data=16, model=16)-shaped Mesh stand-in built from 1 real device
    is impossible — instead validate specs against axis-size maps."""
    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        class devices:
            size = 256
    return M()


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divisible_and_structured(arch):
    from repro.distribution.sharding import param_spec
    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: transformer.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    mesh = _mesh_proxy()
    n_sharded = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
        spec = param_spec(path, leaf, mesh)
        assert len(spec) == leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            size = (np.prod([mesh.shape[a] for a in ax])
                    if isinstance(ax, tuple) else mesh.shape[ax])
            assert dim % size == 0, (path, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: no parameter got sharded"


@pytest.mark.parametrize("arch", ["llama4-scout-17b-a16e", "jamba-v0.1-52b",
                                  "mistral-nemo-12b"])
def test_large_params_are_2d_sharded(arch):
    """Every ≥50M-param leaf must shard on ≥1 axis (memory budget, DESIGN §4)."""
    from repro.distribution.sharding import param_spec
    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: transformer.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    mesh = _mesh_proxy()
    for path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
        n = int(np.prod(leaf.shape))
        if n < 50e6:
            continue
        spec = param_spec(path, leaf, mesh)
        assert any(ax is not None for ax in spec), (path, leaf.shape)


def test_cache_specs_cover_long_context():
    from repro.distribution.sharding import input_shardings
    cfg = get_config("jamba-v0.1-52b")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    specs = model_zoo.input_specs(cfg, SHAPES["long_500k"])
    sh = input_shardings(specs, mesh, 1)
    assert jax.tree.structure(sh, is_leaf=lambda x: hasattr(x, "spec")) \
        == jax.tree.structure(specs, is_leaf=lambda x: hasattr(x, "shape"))


def test_ring_allreduce_int8(mesh8):
    """Numerics + int8 wire format, on the in-process 8-device mesh
    (conftest sets the host-platform device count for the whole session)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    from repro.distribution.compression import ring_allreduce_int8

    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 1000))
                    .astype(np.float32))
    f = shard_map(functools.partial(ring_allreduce_int8, axis_name="d",
                                    axis_size=8),
                  mesh=mesh8, in_specs=P("d"), out_specs=P("d"),
                  check_vma=False)
    out = f(x)
    ref = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
    err = float(jnp.max(jnp.abs(out - ref)))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    # int8 ring: ≤ 1 rounding per hop, 7 hops on the reduce path
    assert err < scale * 8, (err, scale)
    # wire ops are int8: check the HLO
    hlo = jax.jit(f).lower(x).compile().as_text()
    assert "collective-permute" in hlo
    perms = re.findall(r"(s8|s32|f32)\[[^\]]*\][^=]*collective-permute",
                       hlo) or re.findall(
                       r"= \(?(s8|s32|f32)\[[^\]]*\].*collective-permute",
                       hlo)
    assert "s8" in perms, perms


def test_quantize_int8_roundtrip_bounds():
    """Round-trip error of one quantise→dequantise is ≤ scale/2 for values
    inside the representable range, and saturates (not wraps) outside it."""
    import jax.numpy as jnp
    from repro.distribution.compression import (dequantize_int8,
                                                quantize_int8)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 3, (4096,))
                    .astype(np.float32))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    rt = dequantize_int8(quantize_int8(x, scale), scale)
    assert float(jnp.max(jnp.abs(rt - x))) <= scale / 2 + 1e-7
    # out-of-range values clip to ±127·scale — saturation, never wraparound
    big = jnp.asarray([1e6, -1e6], jnp.float32)
    rt_big = dequantize_int8(quantize_int8(big, scale), scale)
    np.testing.assert_allclose(rt_big, [127 * scale, -127 * scale],
                               rtol=1e-6)


def test_shared_scale_headroom(mesh8):
    """The ring's shared scale carries axis_size× headroom: a partial sum
    of all shards' worst-case values still quantises without clipping, even
    when per-shard maxima differ by orders of magnitude."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    from repro.distribution.compression import (dequantize_int8,
                                                quantize_int8, shared_scale)

    # shard i's max is 10^(i/3): local scales would differ ~200×
    x = jnp.stack([jnp.full((64,), 10.0 ** (i / 3), jnp.float32)
                   for i in range(8)])
    f = shard_map(lambda s: shared_scale(s, "d", 8)[None], mesh=mesh8,
                  in_specs=P("d"), out_specs=P("d"), check_vma=False)
    scales = np.asarray(f(x)).reshape(-1)
    expect = float(jnp.max(jnp.abs(x))) * 8 / 127.0
    np.testing.assert_allclose(scales, expect, rtol=1e-6)  # replicated
    # worst-case running accumulation: the full cross-shard sum
    total = jnp.sum(x, axis=0)
    rt = dequantize_int8(quantize_int8(total, scales[0]), scales[0])
    err = float(jnp.max(jnp.abs(rt - total)))
    assert err <= scales[0] / 2 + 1e-5, (err, scales[0])  # rounding, no clip


def test_ring_allreduce_int8_sum_mode_replicated(mesh8):
    """mean=False matches psum semantics, and the output is bit-identical
    on every shard — replicated while_loop stop decisions depend on it."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    from repro.distribution.compression import ring_allreduce_int8

    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (8, 257))
                    .astype(np.float32))                 # 257 ∤ 8: pad path
    f = shard_map(functools.partial(ring_allreduce_int8, axis_name="d",
                                    axis_size=8, mean=False),
                  mesh=mesh8, in_specs=P("d"), out_specs=P("d"),
                  check_vma=False)
    out = np.asarray(f(x)).reshape(8, -1)
    ref = np.asarray(jnp.sum(x, 0))
    scale = float(jnp.max(jnp.abs(x))) * 8 / 127.0
    assert float(np.max(np.abs(out[0] - ref))) < scale * 8
    for r in range(1, 8):                                # bit-identical
        np.testing.assert_array_equal(out[r], out[0])


def test_compress_with_feedback_shared_scale_residual(mesh8):
    """Regression for the EF scale mismatch: when the reduce path is the
    ring (shared pmax·N scale), the residual must model THAT quantisation,
    not the local max(|g|)/127 one — with per-shard maxima orders of
    magnitude apart the two scales differ wildly."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    from repro.distribution.compression import (
        compress_with_feedback, dequantize_int8, init_error_feedback,
        quantize_int8, ring_allreduce_int8, shared_scale)

    rng = np.random.default_rng(3)
    x = jnp.asarray(np.stack([rng.normal(0, 10.0 ** (i / 3), (128,))
                              for i in range(8)]).astype(np.float32))

    def shard_fn(g):
        reduced, new_e = compress_with_feedback(
            (g,), init_error_feedback((g,)),
            reduce_fn=functools.partial(ring_allreduce_int8, axis_name="d",
                                        axis_size=8, mean=False),
            scale_fn=lambda t: shared_scale(t, "d", 8))
        return new_e[0]

    f = shard_map(shard_fn, mesh=mesh8, in_specs=P("d"), out_specs=P("d"),
                  check_vma=False)
    new_e = np.asarray(f(x))
    # the ring quantises with the SHARED scale; residual must match it
    s = jnp.max(jnp.abs(x)) * 8 / 127.0
    ref_e = np.asarray(x - dequantize_int8(quantize_int8(x, s), s))
    np.testing.assert_allclose(new_e, ref_e, rtol=1e-6, atol=1e-7)
    # and must NOT be the local-scale residual on the small-magnitude shard
    s0 = jnp.max(jnp.abs(x[0])) / 127.0
    local_e0 = np.asarray(x[0] - dequantize_int8(quantize_int8(x[0], s0),
                                                 s0))
    assert float(np.max(np.abs(new_e[0] - local_e0))) > float(s0)


def test_ring_wire_bytes_factor():
    from repro.distribution.compression import ring_wire_bytes
    assert ring_wire_bytes(1000, 1) == 0        # nothing moves on 1 device
    assert ring_wire_bytes(1000, 2) == 1000     # 2·(1/2) × payload
    assert ring_wire_bytes(1000, 8) == 1750     # 2·(7/8) × payload


def test_activation_rules_cover_known_names():
    from repro.distribution.sharding import activation_rules
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = activation_rules(mesh)
    for name in ("act_btd", "act_bshd", "act_btf", "logits_btv",
                 "moe_ecd", "moe_ecf"):
        assert name in rules.table
