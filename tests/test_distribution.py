"""Sharding rules + int8 ring all-reduce (in-process 8-device mesh)."""
import functools
import re

import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs, SHAPES
from repro.models import transformer, model_zoo


def _mesh_proxy():
    """A (data=16, model=16)-shaped Mesh stand-in built from 1 real device
    is impossible — instead validate specs against axis-size maps."""
    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        class devices:
            size = 256
    return M()


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divisible_and_structured(arch):
    from repro.distribution.sharding import param_spec
    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: transformer.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    mesh = _mesh_proxy()
    n_sharded = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
        spec = param_spec(path, leaf, mesh)
        assert len(spec) == leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            size = (np.prod([mesh.shape[a] for a in ax])
                    if isinstance(ax, tuple) else mesh.shape[ax])
            assert dim % size == 0, (path, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: no parameter got sharded"


@pytest.mark.parametrize("arch", ["llama4-scout-17b-a16e", "jamba-v0.1-52b",
                                  "mistral-nemo-12b"])
def test_large_params_are_2d_sharded(arch):
    """Every ≥50M-param leaf must shard on ≥1 axis (memory budget, DESIGN §4)."""
    from repro.distribution.sharding import param_spec
    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: transformer.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    mesh = _mesh_proxy()
    for path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
        n = int(np.prod(leaf.shape))
        if n < 50e6:
            continue
        spec = param_spec(path, leaf, mesh)
        assert any(ax is not None for ax in spec), (path, leaf.shape)


def test_cache_specs_cover_long_context():
    from repro.distribution.sharding import input_shardings
    cfg = get_config("jamba-v0.1-52b")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    specs = model_zoo.input_specs(cfg, SHAPES["long_500k"])
    sh = input_shardings(specs, mesh, 1)
    assert jax.tree.structure(sh, is_leaf=lambda x: hasattr(x, "spec")) \
        == jax.tree.structure(specs, is_leaf=lambda x: hasattr(x, "shape"))


def test_ring_allreduce_int8(mesh8):
    """Numerics + int8 wire format, on the in-process 8-device mesh
    (conftest sets the host-platform device count for the whole session)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    from repro.distribution.compression import ring_allreduce_int8

    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 1000))
                    .astype(np.float32))
    f = shard_map(functools.partial(ring_allreduce_int8, axis_name="d",
                                    axis_size=8),
                  mesh=mesh8, in_specs=P("d"), out_specs=P("d"),
                  check_vma=False)
    out = f(x)
    ref = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
    err = float(jnp.max(jnp.abs(out - ref)))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    # int8 ring: ≤ 1 rounding per hop, 7 hops on the reduce path
    assert err < scale * 8, (err, scale)
    # wire ops are int8: check the HLO
    hlo = jax.jit(f).lower(x).compile().as_text()
    assert "collective-permute" in hlo
    perms = re.findall(r"(s8|s32|f32)\[[^\]]*\][^=]*collective-permute",
                       hlo) or re.findall(
                       r"= \(?(s8|s32|f32)\[[^\]]*\].*collective-permute",
                       hlo)
    assert "s8" in perms, perms


def test_activation_rules_cover_known_names():
    from repro.distribution.sharding import activation_rules
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = activation_rules(mesh)
    for name in ("act_btd", "act_bshd", "act_btf", "logits_btv",
                 "moe_ecd", "moe_ecf"):
        assert name in rules.table
