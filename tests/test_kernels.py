"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (the ops
dispatch through the backend registry: interpret on CPU CI, compiled on
TPU/GPU — see tests/test_dispatch.py for the registry itself)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.kmeans_assign.ops import kmeans_assign_chunked
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
from repro.kernels.gmm_estep import gmm_estep
from repro.kernels.gmm_estep.ref import gmm_estep_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n,d,k", [
    (64, 2, 2), (1000, 4, 8), (1024, 3, 6), (777, 11, 10), (128, 130, 3),
    (2048, 4, 16), (31, 7, 5),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_kmeans_assign_sweep(n, d, k, dtype):
    x = jnp.asarray(RNG.normal(0, 10, (n, d)).astype(dtype))
    c = jnp.asarray(RNG.normal(0, 10, (k, d)).astype(dtype))
    l1, s1, n1, j1 = kmeans_assign(x, c)
    l2, s2, n2, j2 = kmeans_assign_ref(x, c)
    assert (l1 == l2).all()
    np.testing.assert_allclose(s1, s2, rtol=2e-5, atol=1e-2)
    np.testing.assert_allclose(n1, n2, rtol=0)
    np.testing.assert_allclose(j1, j2[0], rtol=2e-5)


@given(n=st.integers(8, 300), d=st.integers(1, 24), k=st.integers(2, 12))
@settings(max_examples=12, deadline=None)
def test_kmeans_assign_property(n, d, k):
    rng = np.random.default_rng(n * 31 + d * 7 + k)
    x = jnp.asarray(rng.normal(0, 5, (n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 5, (k, d)).astype(np.float32))
    labels, sums, counts, j = kmeans_assign(x, c)
    # invariants: counts sum to n; sums consistent with labels; J ≥ 0
    assert float(jnp.sum(counts)) == n
    assert float(j) >= 0
    ref_sums = np.zeros((k, d), np.float32)
    np.add.at(ref_sums, np.asarray(labels), np.asarray(x))
    np.testing.assert_allclose(sums, ref_sums, rtol=2e-4, atol=1e-2)


@pytest.mark.parametrize("n,d,k", [(64, 2, 2), (1000, 4, 8), (777, 11, 10),
                                   (2048, 3, 6)])
def test_gmm_estep_sweep(n, d, k):
    x = jnp.asarray(RNG.normal(0, 3, (n, d)).astype(np.float32))
    mu = jnp.asarray(RNG.normal(0, 3, (k, d)).astype(np.float32))
    var = jnp.asarray(RNG.uniform(0.5, 4, (k, d)).astype(np.float32))
    lw = jnp.log(jnp.full((k,), 1.0 / k, jnp.float32))
    o1 = gmm_estep(x, mu, var, lw)
    o2 = gmm_estep_ref(x, mu, var, lw)
    assert (o1[0] == o2[0]).all()
    np.testing.assert_allclose(o1[1], o2[1][0], rtol=1e-5)
    np.testing.assert_allclose(o1[2], o2[2], rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(o1[3], o2[3], rtol=2e-4, atol=2e-2)
    np.testing.assert_allclose(o1[4], o2[4], rtol=2e-4, atol=2e-1)


def test_gmm_estep_responsibilities_sum():
    n, d, k = 500, 4, 6
    x = jnp.asarray(RNG.normal(0, 2, (n, d)).astype(np.float32))
    mu = jnp.asarray(RNG.normal(0, 2, (k, d)).astype(np.float32))
    var = jnp.ones((k, d), jnp.float32)
    lw = jnp.log(jnp.full((k,), 1.0 / k))
    _, _, r_sum, _, _ = gmm_estep(x, mu, var, lw)
    assert float(jnp.sum(r_sum)) == pytest.approx(n, rel=1e-4)


@pytest.mark.parametrize("b,hq,hkv,s,dh,causal,win,dtype", [
    (2, 4, 2, 256, 64, True, None, jnp.float32),
    (1, 8, 8, 128, 64, False, None, jnp.float32),
    (2, 4, 1, 200, 80, True, None, jnp.float32),
    (1, 4, 2, 256, 64, True, 64, jnp.float32),
    (1, 2, 2, 96, 128, True, None, jnp.float32),
    (1, 4, 2, 128, 64, True, None, jnp.bfloat16),
])
def test_flash_attention_sweep(b, hq, hkv, s, dh, causal, win, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, s, dh)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, dh)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, dh)), dtype)
    o1 = flash_attention(q, k, v, causal=causal, window=win)
    o2 = attention_ref(q, k, v, causal=causal, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(o1.astype(jnp.float32)
                                 - o2.astype(jnp.float32)))) < tol


@given(s=st.integers(16, 200), dh=st.sampled_from([32, 64]),
       win=st.one_of(st.none(), st.integers(8, 64)))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(s, dh, win):
    rng = np.random.default_rng(s * 13 + dh)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 2, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 2, s, dh)).astype(np.float32))
    o1 = flash_attention(q, k, v, causal=True, window=win)
    o2 = attention_ref(q, k, v, causal=True, window=win)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-5


def test_flash_attention_xla_backend_is_reference():
    """The registry's xla backend for flash_attention IS the oracle."""
    q = jnp.asarray(RNG.normal(0, 1, (1, 4, 96, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (1, 2, 96, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (1, 2, 96, 32)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, backend="xla")
    o2 = attention_ref(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(o1 - o2))) == 0.0


def test_kmeans_assign_chunked_mask_slices_with_chunks():
    """The shared chunked driver slices the mask alongside the rows."""
    x = jnp.asarray(RNG.normal(0, 5, (300, 4)).astype(np.float32))
    c = jnp.asarray(RNG.normal(0, 5, (5, 4)).astype(np.float32))
    m = jnp.asarray((RNG.random(300) > 0.25).astype(np.float32))
    a = kmeans_assign(x, c, mask=m)
    b = kmeans_assign_chunked(x, c, chunks=4, mask=m)
    assert (a[0] == b[0]).all()
    np.testing.assert_allclose(a[1], b[1], rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(a[3], b[3], rtol=1e-5)


def test_chunked_jnp_attention_matches_exact():
    from repro.models.layers import _sdpa, _sdpa_chunked
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(0, 1, (2, 300, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (2, 300, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (2, 300, 2, 32)).astype(np.float32))
    for causal, win in [(True, None), (False, None), (True, 64)]:
        o1 = _sdpa_chunked(q, k, v, causal=causal, window=win,
                           block_q=64, block_k=128)
        o2 = _sdpa(q, k, v, causal=causal, window=win)
        assert float(jnp.max(jnp.abs(o1 - o2))) < 3e-5
