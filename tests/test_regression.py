"""Regression fitting + model selection (paper Eq. 8, §4)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fit_family, select_model, FAMILIES
from repro.core.regression import pool_traces


def _quad_cloud(b0, b1, b2, noise, n=200, seed=0):
    rng = np.random.default_rng(seed)
    r = rng.uniform(0.3, 1.0, n)
    h = b0 + b1 * r + b2 * r * r + rng.normal(0, noise, n)
    return r, h


def test_quadratic_recovery():
    r, h = _quad_cloud(1.8, -3.6, 1.8, 1e-4)
    m = fit_family(r, h, "quadratic")
    assert np.allclose(m.coeffs, [1.8, -3.6, 1.8], atol=5e-3)
    assert m.metrics.r2 > 0.999


def test_selection_prefers_quadratic_on_quadratic_data():
    r, h = _quad_cloud(1.83, -3.66, 1.83, 5e-4)
    best, table = select_model(r, h)
    assert set(table) == set(FAMILIES)
    # quadratic or cubic (which nests it) must win; linear must not
    assert best.family in ("quadratic", "cubic", "lasso_quadratic")
    assert table["quadratic"].adj_r2 > table["linear"].adj_r2


def test_exponential_fit_on_exponential_data():
    rng = np.random.default_rng(1)
    r = rng.uniform(0.2, 1.0, 300)
    h = 0.5 * np.exp(-6.0 * r)
    m = fit_family(r, h, "exponential")
    assert m.coeffs[0] == pytest.approx(0.5, rel=1e-3)
    assert m.coeffs[1] == pytest.approx(-6.0, rel=1e-3)


@given(st.floats(0.90, 0.999), st.floats(0.5, 3.0))
@settings(max_examples=25, deadline=None)
def test_threshold_monotone_decreasing_in_accuracy(acc, scale):
    """Higher desired accuracy → smaller (or equal) h* (paper Table 2)."""
    r, h = _quad_cloud(scale, -2 * scale, scale, 1e-5, seed=3)
    m = fit_family(r, h, "quadratic")
    assert m.threshold_for(acc) >= m.threshold_for(min(acc + 0.005, 0.9999)) \
        - 1e-12


def test_threshold_floor():
    r, h = _quad_cloud(1.0, -2.0, 1.0, 1e-6)   # h(1) = 0 exactly
    m = fit_family(r, h, "quadratic")
    assert m.threshold_for(1.0) >= 1e-12


def test_pool_traces_filters_nonfinite():
    r, h = pool_traces([(np.array([0.5, np.nan, 0.9]),
                         np.array([0.1, 0.2, np.inf]))])
    assert r.shape == (1,) and h.shape == (1,)


@given(st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_lasso_close_to_ols_with_tiny_penalty(seed):
    """Coefficients of the quadratic basis are ill-conditioned — compare
    the fitted *curves*, not the raw coefficients."""
    r, h = _quad_cloud(1.2, -2.4, 1.2, 1e-4, seed=seed)
    ols = fit_family(r, h, "quadratic")
    lasso = fit_family(r, h, "lasso_quadratic")
    grid = np.linspace(0.3, 1.0, 50)
    scale = float(np.max(np.abs(ols.predict(grid))))
    assert np.allclose(np.asarray(ols.predict(grid)),
                       np.asarray(lasso.predict(grid)),
                       atol=0.05 * scale + 1e-3)
