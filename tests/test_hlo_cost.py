"""The trip-count-aware HLO analyzer — the roofline's foundation."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import (analyze, parse_computations, _parse_op_line,
                                   _type_numel_bytes)


def test_parse_op_line_simple():
    op = _parse_op_line("  %dot.5 = f32[64,128]{1,0} dot(%a, %b), "
                        "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
    assert op.name == "dot.5" and op.opcode == "dot"
    assert _type_numel_bytes(op.rtype) == (64 * 128, 64 * 128 * 4)


def test_parse_op_line_tuple_with_comments():
    line = ("  %while.424 = (s32[], f32[2,1,2,512]{3,2,1,0}, "
            "/*index=5*/f32[4,2,1024,1,64]{4,3,2,1,0}) while(%tuple.367), "
            "condition=%c, body=%b")
    op = _parse_op_line(line)
    assert op.opcode == "while"
    n, b = _type_numel_bytes(op.rtype)
    assert n == 1 + 2 * 2 * 512 + 4 * 2 * 1024 * 64


def test_parse_op_line_root_and_noise():
    assert _parse_op_line("ROOT %t = (f32[2]) tuple(%x)").opcode == "tuple"
    assert _parse_op_line("}") is None
    assert _parse_op_line("// comment") is None


# -- parser edge cases (promoted corpus, pinned against repro.analysis.hlo_ir)


def test_parse_op_line_unsigiled_name():
    # newer XLA dumps print some names without the leading % sigil
    op = _parse_op_line("  add.3 = f32[8]{0} add(%a, %b)")
    assert op is not None and op.name == "add.3" and op.opcode == "add"


def test_parse_op_line_fusion_root():
    op = _parse_op_line(
        "  ROOT %fusion.7 = f32[4,4]{1,0} fusion(%p0, %p1), kind=kLoop, "
        "calls=%fused_computation.3")
    assert op.opcode == "fusion" and op.name == "fusion.7"
    assert "calls=%fused_computation.3" in op.rest


def test_parse_op_line_tuple_tiled_layout():
    # tiled layouts carry a colon inside the layout braces
    op = _parse_op_line(
        "  %t = (f32[64,128]{1,0:T(8,128)}, s8[16]{0:T(1024)(4,1)}) "
        "tuple(%a, %b)")
    assert op.opcode == "tuple"
    n, b = _type_numel_bytes(op.rtype)
    assert n == 64 * 128 + 16 and b == 64 * 128 * 4 + 16


def test_parse_op_line_nested_tuple_type():
    op = _parse_op_line("  %g = ((f32[2]{0}, s32[]), f32[4]{0}) "
                        "get-tuple-element(%w), index=0")
    assert op.opcode == "get-tuple-element"
    assert _type_numel_bytes(op.rtype)[0] == 2 + 1 + 4


def test_parse_computations_multiline_comment():
    hlo = """\
HloModule m

%comp (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  /* a block comment
     spanning several lines
     used to desync the walker */
  ROOT %r = f32[4]{0} add(%p, %p)
}
"""
    comps = parse_computations(hlo)
    assert [o.opcode for o in comps["comp"]] == ["parameter", "add"]


def test_parse_computations_signatureless_header():
    hlo = """\
ENTRY main {
  c = f32[] constant(1)
  ROOT r = f32[] add(c, c)
}
"""
    comps = parse_computations(hlo)
    assert [o.opcode for o in comps["main"]] == ["constant", "add"]


def test_trip_count_dynamic_is_none():
    from repro.analysis.hlo_ir import trip_count
    comps = parse_computations("""\
%cond (s: (s32[], f32[])) -> pred[] {
  %s = (s32[], f32[]) parameter(0)
  %v = f32[] get-tuple-element(%s), index=1
  %z = f32[] get-tuple-element(%s), index=1
  ROOT %lt = pred[] compare(%v, %z), direction=LT
}
""")
    assert trip_count(comps["cond"]) is None


def test_hlo_cost_shim_reexports():
    # the historical import surface survives the promotion
    import repro.analysis.hlo_ir as hlo_ir
    import repro.launch.hlo_cost as hlo_cost
    assert hlo_cost.analyze is hlo_ir.analyze
    assert hlo_cost._parse_op_line is hlo_ir.parse_op_line
    assert hlo_cost._type_numel_bytes is hlo_ir.type_numel_bytes
    assert hlo_cost.COLLECTIVES is hlo_ir.COLLECTIVES


@pytest.fixture(scope="module")
def scan_hlo():
    """Compile a sharded scan on the in-process 8-device host platform
    (conftest sets the device count session-wide) and return its HLO."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if jax.device_count() < 8:
        pytest.skip(f"needs 8 devices, have {jax.device_count()}")

    def f(w, x):
        def step(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(step, x, None, length=12)
        return jnp.sum(h)

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    ws = NamedSharding(mesh, P(None, "model"))
    xs = NamedSharding(mesh, P("data", None))
    with mesh:
        c = jax.jit(f, in_shardings=(ws, xs)).lower(
            jax.ShapeDtypeStruct((256, 256), jnp.float32),
            jax.ShapeDtypeStruct((128, 256), jnp.float32)).compile()
    return c.as_text()


def test_trip_count_multiplication_exact(scan_hlo):
    c = analyze(scan_hlo)
    # 12 iterations × 2·(128/4)·256·256 flops per device (model-sharded dot)
    exact = 12 * 2 * (128 // 4) * 256 * (256 // 2)
    assert c.flops == pytest.approx(exact, rel=1e-6)
    assert c.dynamic_loops == 0


def test_collectives_scaled_by_trips(scan_hlo):
    c = analyze(scan_hlo)
    # per-iteration all-gather of [32,256] f32 → ×12
    assert c.coll.get("all-gather", 0) == pytest.approx(12 * 32 * 256 * 4,
                                                        rel=1e-6)


def test_bytes_nonzero_and_bounded(scan_hlo):
    c = analyze(scan_hlo)
    assert c.bytes > 0
    # loose sanity: not more than 100× the dot operand traffic
    assert c.bytes < 100 * 12 * (32 * 256 + 256 * 128 + 32 * 128) * 4


def test_computation_parser_finds_loop_bodies(scan_hlo):
    comps = parse_computations(scan_hlo)
    assert len(comps) > 3
    assert any(any(o.opcode == "while" for o in ops)
               for ops in comps.values())
