"""Kernel dispatch layer (ISSUE 4): registry resolution and override
hooks, kernel-vs-reference parity goldens on every backend available in
CI (interpret + xla at minimum), vmapped-restarts kernel vs ``vmap`` of
the reference, the GPU split-reduction grid checked under the
interpreter, and minibatch+kernel vs minibatch+XLA producing identical
stop iterations on the seeded blobs fixture."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.engine import ClusteringEngine, EngineConfig
from repro.kernels import dispatch, layout
from repro.kernels.kmeans_assign import ops as kops
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
from repro.kernels.gmm_estep import ops as gops
from repro.kernels.gmm_estep.ref import gmm_estep_ref
from repro.kernels.flash_attention import ops as fops  # noqa: F401  (registers)

K = 4

# every backend the CI host can actually execute (tpu/gpu need hardware)
CI_BACKENDS = [b for b in ("interpret", "xla")]


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(3)
    centers = np.array([[0, 0, 0], [8, 8, 8], [-8, 8, 0], [8, -8, 4]], float)
    x = np.concatenate([c + rng.normal(0, 1.0, (400, 3)) for c in centers])
    x = x[rng.permutation(len(x))]
    return jnp.asarray(x.astype(np.float32))


# --------------------------------------------------------------------------
# Registry mechanics
# --------------------------------------------------------------------------

def test_registry_lists_all_ops_and_backends():
    ops = dispatch.registered_ops()
    for name in ("kmeans_assign", "gmm_estep"):
        assert set(dispatch.KNOWN_BACKENDS) <= set(ops[name]), ops
    # flash_attention deliberately has no gpu registration (sequential-grid
    # online softmax — see test_flash_attention_has_no_gpu_backend)
    assert {"tpu", "interpret", "xla"} <= set(ops["flash_attention"]), ops


def test_default_backend_resolution():
    # this suite runs on CPU (or any non-accelerator host): auto → interpret
    assert dispatch.resolve_backend(None, None) == dispatch.default_backend()
    assert dispatch.resolve_backend("xla") == "xla"
    assert dispatch.resolve_backend(None, interpret=True) == "interpret"
    # a name no op registered fails at the per-op lookup, with guidance
    with pytest.raises(NotImplementedError, match="no 'mosaic' backend"):
        dispatch.get_op("kmeans_assign").impl("mosaic")


def test_force_backend_context():
    before = dispatch.default_backend()
    with dispatch.force_backend("xla"):
        assert dispatch.default_backend() == "xla"
        with dispatch.force_backend("interpret"):
            assert dispatch.default_backend() == "interpret"
        assert dispatch.default_backend() == "xla"
    assert dispatch.default_backend() == before


def test_register_backend_hook_forces_any_path():
    """Tests can route a public op through an arbitrary implementation."""
    calls = []

    def fake(x, w, c, *, block_n):
        calls.append(block_n)
        return dispatch.get_op("kmeans_assign").impl("xla")[1](
            x, w, c, block_n=block_n)

    dispatch.register_backend("kmeans_assign", "fake", fake)
    try:
        x = jnp.ones((32, 3), jnp.float32)
        c = jnp.asarray([[0.0, 0, 0], [2, 2, 2]], jnp.float32)
        labels, _, counts, _ = kops.kmeans_assign(x, c, backend="fake")
        assert calls, "registered hook was not dispatched to"
        assert float(jnp.sum(counts)) == 32
    finally:
        dispatch.get_op("kmeans_assign")._impls.pop("fake")
    with pytest.raises(NotImplementedError, match="no 'fake' backend"):
        kops.kmeans_assign(x, c, backend="fake")


@pytest.mark.skipif(bool(os.environ.get("REPRO_FORCE_KERNEL_BACKEND")),
                    reason="the env hook pins the backend before the "
                           "force_backend context can")
def test_engine_config_resolves_backend_eagerly():
    """The concrete backend is baked into the static config at
    construction — a dispatch.force_backend() active NOW is honoured, and
    the jit caches (keyed on the config) can never cross backends."""
    with dispatch.force_backend("xla"):
        cfg = EngineConfig(use_kernel=True)
    assert cfg.kernel_backend == "xla"
    cfg2 = EngineConfig(use_kernel=True)
    assert cfg2.kernel_backend == dispatch.default_backend()
    assert cfg != cfg2          # distinct jit cache entries


# --------------------------------------------------------------------------
# Parity goldens: op vs reference on every CI-runnable backend
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", CI_BACKENDS)
@pytest.mark.parametrize("n,d,k", [(777, 11, 10), (64, 2, 2), (1024, 3, 6)])
def test_kmeans_assign_backend_parity(backend, n, d, k):
    rng = np.random.default_rng(n + d)
    x = jnp.asarray(rng.normal(0, 10, (n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 10, (k, d)).astype(np.float32))
    l1, s1, n1, j1 = kops.kmeans_assign(x, c, backend=backend)
    l2, s2, n2, j2 = kmeans_assign_ref(x, c)
    assert (l1 == l2).all()
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=1e-2)
    np.testing.assert_allclose(n1, n2, rtol=0)
    np.testing.assert_allclose(j1, j2[0], rtol=2e-5)


@pytest.mark.parametrize("backend", CI_BACKENDS)
def test_gmm_estep_backend_parity(backend):
    rng = np.random.default_rng(0)
    n, d, k = 1000, 4, 8
    x = jnp.asarray(rng.normal(0, 3, (n, d)).astype(np.float32))
    mu = jnp.asarray(rng.normal(0, 3, (k, d)).astype(np.float32))
    var = jnp.asarray(rng.uniform(0.5, 4, (k, d)).astype(np.float32))
    lw = jnp.log(jnp.full((k,), 1.0 / k, jnp.float32))
    o1 = gops.gmm_estep(x, mu, var, lw, backend=backend)
    o2 = gmm_estep_ref(x, mu, var, lw)
    assert (o1[0] == o2[0]).all()
    np.testing.assert_allclose(o1[1], o2[1][0], rtol=1e-5)
    np.testing.assert_allclose(o1[2], o2[2], rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(o1[3], o2[3], rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("backend", CI_BACKENDS)
def test_masked_rows_drop_from_stats(backend):
    """The mask operand (engine chunk padding / subsample weighting): rows
    with weight 0 are labelled -1 and contribute nothing."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 5, (200, 3)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 5, (4, 3)).astype(np.float32))
    m = jnp.asarray((np.arange(200) < 150).astype(np.float32))
    lm, sm, nm, jm = kops.kmeans_assign(x, c, mask=m, backend=backend)
    lt, st, nt, jt = kops.kmeans_assign(x[:150], c, backend=backend)
    assert (np.asarray(lm)[150:] == -1).all()
    assert (np.asarray(lm)[:150] == np.asarray(lt)).all()
    np.testing.assert_allclose(sm, st, rtol=2e-4, atol=1e-2)
    np.testing.assert_allclose(nm, nt, rtol=0)
    np.testing.assert_allclose(jm, jt, rtol=1e-5)


# --------------------------------------------------------------------------
# Restart axis: vmapped kernel vs vmap of the reference
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", CI_BACKENDS)
def test_vmapped_restarts_kernel_vs_vmapped_reference(backend):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 8, (513, 5)).astype(np.float32))
    cr = jnp.asarray(rng.normal(0, 8, (3, 6, 5)).astype(np.float32))
    vm = jax.vmap(lambda c: kops.kmeans_assign(x, c, backend=backend))(cr)
    rf = jax.vmap(lambda c: kmeans_assign_ref(x, c))(cr)
    assert (vm[0] == rf[0]).all()
    np.testing.assert_allclose(vm[1], rf[1], rtol=2e-4, atol=1e-2)
    np.testing.assert_allclose(vm[3], rf[3][:, 0], rtol=2e-5)

    mu = jnp.asarray(rng.normal(0, 2, (3, 6, 5)).astype(np.float32))
    var = jnp.asarray(rng.uniform(0.5, 2, (3, 6, 5)).astype(np.float32))
    lw = jnp.broadcast_to(jnp.log(jnp.full((6,), 1 / 6, jnp.float32)), (3, 6))
    gv = jax.vmap(lambda m, v, w: gops.gmm_estep(x, m, v, w,
                                                 backend=backend))(mu, var, lw)
    gr = jax.vmap(lambda m, v, w: gmm_estep_ref(x, m, v, w))(mu, var, lw)
    assert (gv[0] == gr[0]).all()
    np.testing.assert_allclose(gv[1], gr[1][:, 0], rtol=1e-5)


def test_vmapped_points_and_params():
    """Per-restart minibatch draws batch the points too: both x and the
    params ride the restart grid."""
    rng = np.random.default_rng(2)
    xr = jnp.asarray(rng.normal(0, 5, (2, 100, 3)).astype(np.float32))
    cr = jnp.asarray(rng.normal(0, 5, (2, 4, 3)).astype(np.float32))
    vm = jax.vmap(kops.kmeans_assign)(xr, cr)
    for r in range(2):
        lr, sr, nr, jr = kmeans_assign_ref(xr[r], cr[r])
        assert (vm[0][r] == lr).all()
        np.testing.assert_allclose(vm[3][r], jr[0], rtol=2e-5)


def test_gpu_split_reduction_grid_matches_reference():
    """The GPU backend's parallel-grid variant (per-step partials, no
    cross-step accumulation) — its math checked under the interpreter with
    the GPU tile policy, since CI has no GPU."""
    from repro.kernels.kmeans_assign.kernel import kmeans_assign_kernel
    rng = np.random.default_rng(4)
    n, d, k = 700, 5, 6
    x = jnp.asarray(rng.normal(0, 5, (n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 5, (k, d)).astype(np.float32))
    pol = layout.tile_policy("gpu")
    bn = pol.block_for(n)
    npad = layout.round_up(n, bn)
    dpad = pol.align_d(d)
    kpad = pol.align_k(k)
    # Triton block shapes must be powers of two — the gpu policy's padded
    # dims must come out pow2 even for awkward inputs
    assert all(v & (v - 1) == 0 for v in (bn, dpad, kpad)), (bn, dpad, kpad)
    xp = jnp.pad(x, ((0, npad - n), (0, dpad - d)))[None]
    wp = jnp.pad(jnp.ones((n,), jnp.float32), (0, npad - n))[None]
    cp = jnp.pad(c, ((0, kpad - k), (0, dpad - d)))
    cp = cp.at[k:, :].set(1e9)[None]
    lab, sums, counts, j = kmeans_assign_kernel(
        xp, wp, cp, block_n=bn, interpret=True, accumulate=False)
    assert sums.shape[1] == npad // bn        # one partial per grid step
    l2, s2, n2, j2 = kmeans_assign_ref(x, c)
    assert (lab[0, :n] == l2).all()
    np.testing.assert_allclose(jnp.sum(sums, 1)[0, :k, :d], s2,
                               rtol=2e-4, atol=1e-2)
    np.testing.assert_allclose(jnp.sum(counts, 1)[0, :k], n2, rtol=0)
    np.testing.assert_allclose(jnp.sum(j, 1)[0, 0], j2[0], rtol=2e-5)


def test_gpu_split_reduction_grid_gmm_matches_reference():
    """Same guard for the gmm_estep accumulate=False variant: per-step
    partials + the wrapper's sum must reproduce the reference."""
    from repro.kernels.gmm_estep.kernel import gmm_estep_kernel
    rng = np.random.default_rng(7)
    n, d, k = 700, 5, 6
    x = jnp.asarray(rng.normal(0, 3, (n, d)).astype(np.float32))
    mu = jnp.asarray(rng.normal(0, 3, (k, d)).astype(np.float32))
    var = jnp.asarray(rng.uniform(0.5, 4, (k, d)).astype(np.float32))
    lw = jnp.log(jnp.full((k,), 1.0 / k, jnp.float32))
    pol = layout.tile_policy("gpu")
    bn = pol.block_for(n)
    npad = layout.round_up(n, bn)
    dpad = pol.align_d(d)
    kpad = pol.align_k(k)
    inv_var = 1.0 / var
    b_op = mu * inv_var
    const = (lw - 0.5 * (jnp.sum(mu ** 2 * inv_var, -1)
                         + jnp.sum(jnp.log(var), -1)
                         + d * 1.8378770664093453))
    xp = jnp.pad(x, ((0, npad - n), (0, dpad - d)))[None]
    wp = jnp.pad(jnp.ones((n,), jnp.float32), (0, npad - n))[None]
    ap = jnp.pad(inv_var, ((0, kpad - k), (0, dpad - d)))[None]
    bp = jnp.pad(b_op, ((0, kpad - k), (0, dpad - d)))[None]
    cp = jnp.pad(const, (0, kpad - k), constant_values=-1e30)[None]
    lab, ll, rs, rx, rx2 = gmm_estep_kernel(
        xp, wp, ap, bp, cp, block_n=bn, interpret=True, accumulate=False)
    assert ll.shape[1] == npad // bn          # one partial per grid step
    o2 = gmm_estep_ref(x, mu, var, lw)
    assert (lab[0, :n] == o2[0]).all()
    np.testing.assert_allclose(jnp.sum(ll, 1)[0, 0], o2[1][0], rtol=1e-5)
    np.testing.assert_allclose(jnp.sum(rs, 1)[0, :k], o2[2],
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(jnp.sum(rx, 1)[0, :k, :d], o2[3],
                               rtol=2e-4, atol=2e-2)
    np.testing.assert_allclose(jnp.sum(rx2, 1)[0, :k, :d], o2[4],
                               rtol=2e-4, atol=2e-1)


def test_flash_attention_has_no_gpu_backend():
    """The flash kernel's online-softmax scratch assumes a sequential kv
    grid axis (TPU); a Triton registration would race across CTAs — ensure
    it stays unregistered (fails loud on GPU hosts) until a split-softmax
    variant exists."""
    op = dispatch.get_op("flash_attention")
    assert "gpu" not in op.backends()
    with pytest.raises(NotImplementedError, match="no 'gpu' backend"):
        op.impl("gpu")


# --------------------------------------------------------------------------
# Engine-level: minibatch+kernel vs minibatch+XLA identical stop iterations
# --------------------------------------------------------------------------

def test_minibatch_kernel_vs_xla_identical_stop(blobs):
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(0), blobs, K)
    kw = dict(mode="minibatch", chunks=8, batch_chunks=2, patience=3,
              max_iters=300, stop_when_frozen=True, use_kernel=True)
    ri = ClusteringEngine("kmeans", EngineConfig(
        kernel_backend="interpret", **kw)).fit(blobs, c0, h_star=1e-4)
    rx = ClusteringEngine("kmeans", EngineConfig(
        kernel_backend="xla", **kw)).fit(blobs, c0, h_star=1e-4)
    assert int(ri.n_iters) == int(rx.n_iters)
    np.testing.assert_allclose(ri.params, rx.params, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(ri.objective), float(rx.objective),
                               rtol=1e-5)


def test_chunked_entry_points_dispatch_per_backend(blobs):
    c = jnp.asarray(np.random.default_rng(6).normal(0, 5, (K, 3)),
                    jnp.float32)
    a = kops.kmeans_assign_chunked(blobs, c, chunks=3, backend="interpret")
    b = kops.kmeans_assign_chunked(blobs, c, chunks=3, backend="xla")
    assert (a[0] == b[0]).all()
    np.testing.assert_allclose(a[3], b[3], rtol=1e-5)
