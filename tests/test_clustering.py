"""k-means + EM engines: convergence invariants, early stop, kernels parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import em_gmm
from repro.data import load


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(0)
    centers = np.array([[0, 0, 0], [8, 8, 8], [-8, 8, 0], [8, -8, 4]], float)
    x = np.concatenate([c + rng.normal(0, 1.0, (500, 3)) for c in centers])
    return x.astype(np.float32)


def test_kmeans_objective_monotone(blobs):
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(0), jnp.asarray(blobs), 4)
    res = core.kmeans_fit_traced(blobs, c0, max_iters=100)
    js = np.asarray(res["objectives"])
    assert np.all(np.diff(js) <= 1e-3 * np.abs(js[:-1]) + 1e-6), \
        "k-means J must be monotonically decreasing (Selim & Ismail 1984)"


def test_kmeans_earlystop_fewer_iters_and_accurate(blobs):
    x = jnp.asarray(blobs)
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(1), x, 4)
    res = core.kmeans_fit_traced(blobs, c0, max_iters=200)
    r, h = core.trace_to_rh(res, 4)
    model = core.fit_longtail([(np.asarray(r), np.asarray(h))],
                              algorithm="kmeans", dataset="blobs",
                              family="quadratic")
    h_star = model.threshold_for(0.95)
    _, labels, _, iters = core.kmeans_fit_earlystop(x, c0, h_star,
                                                    max_iters=200)
    assert int(iters) <= res["n_iters"]
    acc = float(core.rand_index(labels, res["labels"], 4, 4))
    assert acc >= 0.90          # close to the 95% desired accuracy


def test_kmeans_empty_cluster_keeps_centroid():
    x = jnp.asarray(np.array([[0.0, 0], [0.1, 0], [10, 10]], np.float32))
    # one centroid far away from everything → empty after assignment
    c0 = jnp.asarray([[0.0, 0.0], [100.0, 100.0]], jnp.float32)
    c1, labels, j = core.kmeans_step(x, c0)
    assert np.allclose(np.asarray(c1)[1], [100.0, 100.0])
    assert jnp.all(jnp.isfinite(c1))


def test_kmeans_full_equals_traced_final(blobs):
    x = jnp.asarray(blobs)
    c0 = core.random_init(jax.random.PRNGKey(2), x, 4)
    res = core.kmeans_fit_traced(blobs, c0, max_iters=300)
    _, labels, j, iters = core.kmeans_fit_full(x, c0, max_iters=300)
    assert float(core.rand_index(labels, res["labels"], 4, 4)) == \
        pytest.approx(1.0, abs=1e-6)


def test_kernel_path_matches_jnp_path(blobs):
    x = jnp.asarray(blobs[:512])
    c0 = core.random_init(jax.random.PRNGKey(3), x, 4)
    l1, s1, n1, j1 = core.assign_and_stats(x, c0, use_kernel=False)
    l2, s2, n2, j2 = core.assign_and_stats(x, c0, use_kernel=True)
    assert (l1 == l2).all()
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(j1, j2, rtol=1e-5)


def test_em_loglik_monotone(blobs):
    x = jnp.asarray(blobs)
    p0 = em_gmm.random_init(jax.random.PRNGKey(0), x, 4)
    res = em_gmm.em_fit_traced(blobs, p0, max_iters=60, tol=1e-12)
    js = np.asarray(res["objectives"])
    viol = np.diff(js) / np.maximum(np.abs(js[:-1]), 1e-9)
    assert viol.min() > -1e-5, \
        "EM log-likelihood must be non-decreasing up to f32 noise (Wu 1983)"


def test_em_recovers_separated_blobs(blobs):
    x = jnp.asarray(blobs)
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(4), x, 4)
    p0 = em_gmm.init_from_kmeans(x, c0)
    res = em_gmm.em_fit_traced(blobs, p0, max_iters=100, tol=1e-12)
    truth = np.repeat(np.arange(4), 500)
    acc = float(core.rand_index(res["labels"], jnp.asarray(truth), 4, 4))
    assert acc > 0.99


def test_em_kernel_path_matches(blobs):
    x = jnp.asarray(blobs[:512])
    p0 = em_gmm.random_init(jax.random.PRNGKey(5), x, 4)
    o1 = em_gmm.estep_stats(x, p0, use_kernel=False)
    o2 = em_gmm.estep_stats(x, p0, use_kernel=True)
    assert (o1[0] == o2[0]).all()
    np.testing.assert_allclose(o1[1], o2[1], rtol=1e-5)
    for a, b in zip(o1[2:], o2[2:]):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-3)


def test_long_tail_exists_on_paper_like_data():
    """The core phenomenon (Fig. 5): high accuracy reached well before
    convergence on a realistic dataset."""
    x = load("road3d", n=6000, seed=7)
    c0 = core.kmeans_plus_plus_init(jax.random.PRNGKey(6), jnp.asarray(x), 8)
    res = core.kmeans_fit_traced(x, c0, max_iters=300)
    if res["n_iters"] < 10:
        pytest.skip("converged too fast to exhibit a tail")
    r = core.trace_accuracy(res["labels_history"], 8)
    # accuracy at 50% of iterations should already be ≥ 95%
    mid = res["n_iters"] // 2
    assert float(r[mid]) > 0.95
