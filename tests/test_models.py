"""Per-architecture smoke tests (deliverable f): reduced config, one
forward/train step on CPU, output shapes + no NaNs; decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, SHAPES, applicable
from repro.models import (init_lm, lm_loss, prefill, decode_step, init_cache,
                          count_params, input_specs)
from repro.models.transformer import forward

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=24):
    if cfg.encoder_only:
        return {"embeddings": jax.random.normal(KEY, (b, s, cfg.d_model),
                                                cfg.act_dtype) * 0.1,
                "targets": jax.random.randint(KEY, (b, s), 0, cfg.vocab),
                "mask": jnp.ones((b, s), bool)}
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (b, cfg.cross_attn_tokens, cfg.d_model), cfg.act_dtype) * 0.02
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = init_lm(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeddings=batch.get("embeddings"),
                          image_embeds=batch.get("image_embeds"))
    assert logits.shape == (2, 24, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, grads = jax.value_and_grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_shapes_and_counts(arch):
    """FULL configs: structure only (eval_shape — no allocation)."""
    cfg = get_config(arch)
    n = count_params(cfg)
    assert n > 100e6, f"{arch} suspiciously small: {n}"
    for shape in SHAPES.values():
        ok, why = applicable(cfg, shape)
        if not ok:
            assert why
            continue
        specs = input_specs(cfg, shape)
        assert specs, (arch, shape.name)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "gemma3-12b",
                                  "jamba-v0.1-52b", "xlstm-350m",
                                  "llama-3.2-vision-11b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:   # avoid capacity-drop mismatch (tested separately)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_lm(KEY, cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    tokens = batch["tokens"]
    kw = {k: v for k, v in batch.items() if k == "image_embeds"}
    logits_full, _ = forward(params, cfg, tokens=tokens, **kw)
    _, caches = prefill(params, cfg, tokens=tokens[:, :s - 1], **kw)
    cache_full = init_cache(cfg, b, s)
    caches = jax.tree.map(
        lambda d, src: jax.lax.dynamic_update_slice(
            d, src.astype(d.dtype), (0,) * src.ndim)
        if d.shape != src.shape else src.astype(d.dtype),
        cache_full, caches)
    logit_dec, _ = decode_step(params, cfg, tokens[:, s - 1:s], caches,
                               s - 1, **kw)
    ref = logits_full[:, -1].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(ref - logit_dec.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert err / scale < 2e-2, (arch, err, scale)


def test_moe_capacity_drops_are_bounded():
    """Switch-style dropping: with cf=1.0 some tokens drop; output stays
    finite and aux loss is near 1 (balanced) for random inputs."""
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    params = init_lm(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (4, 64), 0, cfg.vocab)}
    loss, metrics = lm_loss(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    assert 0.5 < float(metrics["moe_aux"]) < 4.0


def test_gqa_head_broadcast_consistency():
    """GQA with kv=1 (MQA) equals full MHA with repeated KV heads."""
    from repro.models.layers import _sdpa
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 32, 4, 16)).astype(np.float32))
    k1 = jnp.asarray(rng.normal(0, 1, (1, 32, 1, 16)).astype(np.float32))
    v1 = jnp.asarray(rng.normal(0, 1, (1, 32, 1, 16)).astype(np.float32))
    o1 = _sdpa(q, k1, v1, causal=True, window=None)
    o2 = _sdpa(q, jnp.repeat(k1, 4, 2), jnp.repeat(v1, 4, 2),
               causal=True, window=None)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5


def test_sliding_window_matches_full_when_window_covers_seq():
    cfg = get_config("gemma3-12b", reduced=True)
    cfg_big_win = dataclasses.replace(cfg, sliding_window=10_000)
    cfg_full = dataclasses.replace(
        cfg, period=("attn",) * 5 + ("attn_global",), sliding_window=None)
    params = init_lm(KEY, cfg)
    batch = _batch(cfg)
    l1, _ = forward(params, cfg_big_win, tokens=batch["tokens"])
    l2, _ = forward(params, cfg_full, tokens=batch["tokens"])
    assert float(jnp.max(jnp.abs(l1.astype(jnp.float32)
                                 - l2.astype(jnp.float32)))) < 1e-2
