"""Cluster assignment server (ISSUE 6): bucket-padded continuous batching,
strict provenance admission, and bit-for-bit parity with the engine."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClusterArtifact, ClusteringEngine, EngineConfig,
                        GMMParams, ProvenanceMismatchError, fit_longtail)
from repro.core.longtail_train import config_fingerprint
from repro.kernels.layout import bucket_for, pad_to_bucket
from repro.serving import (AssignRequest, ClusterServer, FitRequest,
                           ModelRegistry)

K, D = 3, 4
BUCKETS = (32, 128, 512)


def _model_for(cfg, algorithm="kmeans"):
    """A cheap stop-model with real provenance (synthetic quadratic tail)."""
    r = np.linspace(0.3, 1.0, 50)
    h = 1.8 - 3.6 * r + 1.8 * r * r
    return fit_longtail([(r, h)], algorithm=algorithm, dataset="t",
                        family="quadratic",
                        engine_config=config_fingerprint(cfg))


def _kmeans_artifact(name, cfg, seed=0):
    rng = np.random.default_rng(seed)
    return ClusterArtifact(
        name=name, algorithm="kmeans",
        params=rng.normal(0, 4, (K, D)).astype(np.float32),
        model=_model_for(cfg, "kmeans"))


MB_CFG = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2, patience=3,
                      max_iters=40)
FULL_CFG = EngineConfig(max_iters=40)


@pytest.fixture()
def server():
    registry = ModelRegistry(fit_steps=10)
    k1 = registry.register(_kmeans_artifact("mb", MB_CFG, seed=0))
    k2 = registry.register(_kmeans_artifact("full", FULL_CFG, seed=1))
    return ClusterServer(registry, buckets=BUCKETS), k1, k2


def _batch(n, seed):
    return np.random.default_rng(seed).normal(0, 4, (n, D)).astype(np.float32)


def test_served_labels_match_engine_bit_for_bit(server):
    srv, k1, k2 = server
    for key, cfg, seed in ((k1, MB_CFG, 3), (k2, FULL_CFG, 4)):
        x = _batch(77, seed)
        srv.submit(AssignRequest(x=x, model_key=key, rid=seed))
        out = srv.drain()
        entry = srv.registry[key]
        eng = ClusteringEngine("kmeans", cfg)
        _, ref_labels, _ = eng.step(x, entry.params)
        np.testing.assert_array_equal(out[seed], np.asarray(ref_labels))
        assert out[seed].shape == (77,)          # padding stripped


def test_mixed_sizes_pack_into_one_bucket_batch(server):
    """Several small requests across two models drain correctly: each rid
    gets its own slice back, equal to serving it alone."""
    srv, k1, k2 = server
    sizes = [5, 31, 12, 64, 3]
    for i, n in enumerate(sizes):
        srv.submit(AssignRequest(x=_batch(n, 100 + i),
                                 model_key=(k1 if i % 2 == 0 else k2),
                                 rid=i))
    out = srv.drain()
    assert set(out) == set(range(len(sizes)))
    for i, n in enumerate(sizes):
        key = k1 if i % 2 == 0 else k2
        entry = srv.registry[key]
        x = _batch(n, 100 + i)
        bucket = bucket_for(n, BUCKETS)
        xp, mask = pad_to_bucket(x, bucket)
        solo, _ = entry.assign(xp, mask, entry.params)
        np.testing.assert_array_equal(out[i], np.asarray(solo)[:n])


def test_bucket_padding_never_changes_compiled_shapes(server):
    """The compile-count probe: many distinct batch sizes, but the jit
    cache only grows with the number of distinct BUCKETS served."""
    srv, k1, _ = server
    entry = srv.registry[k1]
    assert entry.assign._cache_size() == 0
    buckets_used = set()
    for i, n in enumerate([3, 9, 17, 30, 32, 40, 100, 128, 200, 500]):
        srv.submit(AssignRequest(x=_batch(n, 200 + i), model_key=k1,
                                 rid=1000 + i))
        srv.drain()                    # one batch per drain: bucket_for(n)
        buckets_used.add(bucket_for(n, BUCKETS))
        assert entry.assign._cache_size() == len(buckets_used)
    assert buckets_used == set(BUCKETS)     # the probe exercised all three


def test_provenance_mismatch_is_rejected_loudly():
    registry = ModelRegistry()
    art = _kmeans_artifact("mb", MB_CFG)
    with pytest.raises(ProvenanceMismatchError) as ei:
        registry.register(art, overrides={"mode": "full"})
    assert "mode" in ei.value.diff
    assert registry.keys() == []            # nothing half-registered
    # the same artifact registers cleanly under its stamped regime
    registry.register(art)
    assert len(registry.keys()) == 1


def test_from_longtail_strict_raises_not_warns():
    model = _model_for(MB_CFG, "kmeans")
    with pytest.raises(ProvenanceMismatchError):
        EngineConfig.from_longtail(model, 0.95, strict=True, max_iters=40)
    with pytest.warns(UserWarning, match="mode-matched"):
        EngineConfig.from_longtail(model, 0.95, max_iters=40)


def test_admission_rejects_malformed_requests(server):
    srv, k1, _ = server
    with pytest.raises(ValueError, match="unknown model"):
        srv.submit(AssignRequest(x=_batch(5, 0), model_key="nope", rid=0))
    with pytest.raises(ValueError, match="feature width"):
        srv.submit(AssignRequest(x=np.zeros((5, D + 2), np.float32),
                                 model_key=k1, rid=1))
    with pytest.raises(ValueError, match="largest bucket"):
        srv.submit(AssignRequest(x=_batch(BUCKETS[-1] + 1, 0),
                                 model_key=k1, rid=2))
    with pytest.raises(ValueError, match="n >= 1"):
        srv.submit(AssignRequest(x=np.zeros((0, D), np.float32),
                                 model_key=k1, rid=3))
    srv.submit(AssignRequest(x=_batch(5, 0), model_key=k1, rid=4))
    with pytest.raises(ValueError, match="already pending"):
        srv.submit(AssignRequest(x=_batch(5, 1), model_key=k1, rid=4))
    assert 4 in srv.drain()                 # the queue survived the rejects


def test_fit_request_advances_registered_params(server):
    srv, k1, _ = server
    entry = srv.registry[k1]
    before = np.asarray(entry.params).copy()
    x = _batch(300, 7)
    srv.submit(FitRequest(x=x, model_key=k1, rid=50))
    out = srv.drain()
    assert np.isfinite(out[50]["objective"])
    assert 1 <= out[50]["n_iters"] <= 10    # registry fit_steps budget
    after = np.asarray(entry.params)
    assert not np.array_equal(before, after)
    # subsequent assignments are served under the advanced parameters
    srv.submit(AssignRequest(x=x[:20], model_key=k1, rid=51))
    labels = srv.drain()[51]
    from repro.kernels.kmeans_assign import ops as kops
    ref_labels, _, _, _ = kops.kmeans_assign(
        jnp.asarray(x[:20]), entry.params, backend=entry.backend)
    np.testing.assert_array_equal(labels, np.asarray(ref_labels))


def test_metrics_and_summary(server):
    srv, k1, _ = server
    for i, n in enumerate([10, 40, 90]):
        srv.submit(AssignRequest(x=_batch(n, i), model_key=k1, rid=i))
    srv.drain()
    m = srv.metrics.summary()[k1]
    assert m["requests"] == 3 and m["points"] == 140
    assert m["p50_latency_ms"] > 0 and m["p99_latency_ms"] > 0
    assert m["throughput_points_per_s"] > 0 and m["qps"] > 0


def test_em_artifact_roundtrip_and_serving():
    rng = np.random.default_rng(2)
    gmm = GMMParams(means=rng.normal(0, 4, (K, D)).astype(np.float32),
                    var=np.ones((K, D), np.float32),
                    log_w=np.full((K,), -np.log(K), np.float32))
    art = ClusterArtifact(name="em", algorithm="em", params=gmm,
                          model=_model_for(FULL_CFG, "em"))
    again = ClusterArtifact.from_json(art.to_json())
    assert again.algorithm == "em" and again.k == K and again.d == D
    np.testing.assert_array_equal(again.params.means, gmm.means)
    assert json.loads(again.to_json()) == json.loads(art.to_json())

    registry = ModelRegistry()
    key = registry.register(again)
    srv = ClusterServer(registry, buckets=BUCKETS)
    x = _batch(25, 9)
    srv.submit(AssignRequest(x=x, model_key=key, rid=0))
    labels = srv.drain()[0]
    eng = ClusteringEngine("em", FULL_CFG)
    _, ref, _ = eng.step(x, registry[key].params)
    np.testing.assert_array_equal(labels, np.asarray(ref))


def test_warmup_precompiles_every_bucket(server):
    srv, k1, _ = server
    srv.warmup(k1)
    entry = srv.registry[k1]
    assert entry.assign._cache_size() == len(BUCKETS)
    srv.submit(AssignRequest(x=_batch(200, 0), model_key=k1, rid=0))
    srv.drain()
    assert entry.assign._cache_size() == len(BUCKETS)   # no new programs


def test_registry_key_is_provenance_fingerprint():
    registry = ModelRegistry()
    key = registry.register(_kmeans_artifact("mb", MB_CFG))
    assert key.startswith("mb@") and "mode=minibatch" in key
    with pytest.raises(ValueError, match="already registered"):
        registry.register(_kmeans_artifact("mb", MB_CFG))
