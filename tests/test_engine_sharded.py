"""Sharded engine drivers (ISSUE 3): fit_sharded / fit_restarts_sharded
under an in-process 8-device ("data",) mesh must reproduce the
single-device engine — the globally-chunked layout makes every shard's
local chunk a row-slice of the global chunk, so the seeded draw selects
the same subsample and the whole trajectory matches up to fp32 reduction
order (params within tolerance, identical stop iteration).  Since ISSUE 4
the same drivers serve use_kernel=True (per-chunk masked kernel calls
through the backend registry) — parity-tested below for full, minibatch
and vmapped-restart fleets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import em_gmm
from repro.core.engine import ClusteringEngine, EngineConfig

K = 4

# one minibatch recipe for the whole file: 2-of-8 chunks per iteration
MB = dict(mode="minibatch", chunks=8, batch_chunks=2, patience=3,
          max_iters=300, seed=11)


def _data_mesh(mesh8):
    """The sharded drivers shard over the ("pod", "data") axes; mesh8 only
    asserts the 8-device substrate is up (its axis is named "d")."""
    del mesh8
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(1)
    centers = np.array([[0, 0, 0], [9, 9, 9], [-9, 9, 0], [9, -9, 5]], float)
    x = np.concatenate([c + rng.normal(0, 1.0, (512, 3)) for c in centers])
    x = x[rng.permutation(len(x))]             # unbias the chunk contents
    return jnp.asarray(x.astype(np.float32))   # N=2048 = 8 devices · 256


@pytest.fixture(scope="module")
def c0(blobs):
    return core.kmeans_plus_plus_init(jax.random.PRNGKey(0), blobs, K)


# --------------------------------------------------------------------------
# Single-fit parity: sharded minibatch == single-device minibatch
# --------------------------------------------------------------------------

def test_sharded_minibatch_kmeans_matches_single_device(blobs, c0, mesh8):
    eng = ClusteringEngine("kmeans", EngineConfig(stop_when_frozen=True,
                                                  **MB))
    ref = eng.fit(blobs, c0, h_star=1e-4)
    res = eng.fit_sharded(blobs, c0, _data_mesh(mesh8), h_star=1e-4)
    assert int(res.n_iters) == int(ref.n_iters)
    np.testing.assert_allclose(res.params, ref.params, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(res.objective), float(ref.objective),
                               rtol=1e-5)
    assert res.labels.shape == ref.labels.shape
    assert float((res.labels == ref.labels).mean()) > 0.999


def test_sharded_minibatch_em_matches_single_device(blobs, c0, mesh8):
    p0 = em_gmm.init_from_kmeans(blobs, c0)
    eng = ClusteringEngine("em", EngineConfig(**MB))
    ref = eng.fit(blobs, p0, h_star=1e-4)
    res = eng.fit_sharded(blobs, p0, _data_mesh(mesh8), h_star=1e-4)
    assert int(res.n_iters) == int(ref.n_iters)
    np.testing.assert_allclose(res.params.means, ref.params.means,
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(res.params.var, ref.params.var,
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(res.objective), float(ref.objective),
                               rtol=1e-5)
    assert float((res.labels == ref.labels).mean()) > 0.999


def test_sharded_minibatch_uneven_rows(mesh8):
    """N not divisible by chunks x devices: the padded chunk layout must
    keep every real row (no shard_points-style truncation) and still match
    the single-device fit."""
    rng = np.random.default_rng(2)
    x = np.concatenate([c + rng.normal(0, 0.8, (333, 2))
                        for c in ([0, 0], [10, 10], [-10, 6], [9, -9])])
    x = jnp.asarray(x[rng.permutation(len(x))].astype(np.float32))  # N=1332
    c0u = core.kmeans_plus_plus_init(jax.random.PRNGKey(3), x, K)
    eng = ClusteringEngine("kmeans", EngineConfig(stop_when_frozen=True,
                                                  **MB))
    ref = eng.fit(x, c0u, h_star=1e-4)
    res = eng.fit_sharded(x, c0u, _data_mesh(mesh8), h_star=1e-4)
    assert res.labels.shape[0] == x.shape[0]
    assert int(res.n_iters) == int(ref.n_iters)
    np.testing.assert_allclose(res.params, ref.params, rtol=1e-4, atol=1e-4)
    assert float((res.labels == ref.labels).mean()) > 0.999


def test_sharded_full_mode_matches_single_device(blobs, c0, mesh8):
    """fit_sharded is mode-agnostic: full-batch chunk sweeps under the same
    layout agree with the flat single-device path."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, chunks=4, stop_when_frozen=True))
    ref = eng.fit(blobs, c0, h_star=1e-4)
    res = eng.fit_sharded(blobs, c0, _data_mesh(mesh8), h_star=1e-4)
    assert int(res.n_iters) == int(ref.n_iters)
    np.testing.assert_allclose(res.params, ref.params, rtol=1e-5, atol=1e-4)
    assert float((res.labels == ref.labels).mean()) > 0.999


# --------------------------------------------------------------------------
# Multi-restart parity: vmapped restarts inside shard_map (vmap-of-psum)
# --------------------------------------------------------------------------

def test_sharded_restarts_minibatch_best_j_parity(blobs, mesh8):
    """--restarts 4 --shard: per-restart chunk streams + stop masks under
    shard_map must reproduce the unsharded fit_restarts fleet — same best
    index, objectives within fp tolerance, stop iterations within the one
    boundary step fp reduction order can flip."""
    eng = ClusteringEngine("kmeans", EngineConfig(stop_when_frozen=True,
                                                  **MB))
    params0 = eng.init_restarts(jax.random.PRNGKey(9), blobs, K, 4)
    ref = eng.fit_restarts(blobs, params0, h_star=1e-4)
    rr = eng.fit_restarts_sharded(blobs, params0, _data_mesh(mesh8),
                                  h_star=1e-4)
    assert rr.objectives.shape == (4,)
    assert int(rr.best_index) == int(ref.best_index)
    np.testing.assert_allclose(rr.objectives, ref.objectives, rtol=1e-3)
    np.testing.assert_allclose(float(rr.best.objective),
                               float(ref.best.objective), rtol=1e-4)
    assert np.max(np.abs(np.asarray(rr.n_iters, np.int64)
                         - np.asarray(ref.n_iters, np.int64))) <= 1
    np.testing.assert_allclose(rr.best.params, ref.best.params,
                               rtol=1e-3, atol=1e-2)
    assert float((rr.best.labels == ref.best.labels).mean()) > 0.999


def test_sharded_restarts_full_mode_parity(blobs, mesh8):
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, chunks=4, stop_when_frozen=True))
    params0 = eng.init_restarts(jax.random.PRNGKey(2), blobs, K, 3)
    ref = eng.fit_restarts(blobs, params0, h_star=1e-4)
    rr = eng.fit_restarts_sharded(blobs, params0, _data_mesh(mesh8),
                                  h_star=1e-4)
    assert int(rr.best_index) == int(ref.best_index)
    np.testing.assert_array_equal(np.asarray(rr.n_iters),
                                  np.asarray(ref.n_iters))
    np.testing.assert_allclose(rr.objectives, ref.objectives, rtol=1e-5)
    np.testing.assert_allclose(rr.best.params, ref.best.params,
                               rtol=1e-5, atol=1e-4)


def test_sharded_restarts_em_runs(blobs, c0, mesh8):
    """EM restarts under shard_map: pytree (GMMParams) specs + soft-count
    stepwise updates compose; the best restart must carry the max loglik."""
    eng = ClusteringEngine("em", EngineConfig(**MB))
    rr = eng.fit_restarts_sharded(blobs, mesh=_data_mesh(mesh8),
                                  key=jax.random.PRNGKey(4), k=K, restarts=3,
                                  h_star=1e-4)
    best = int(np.argmax(np.asarray(rr.objectives)))
    assert int(rr.best_index) == best
    np.testing.assert_allclose(float(rr.best.objective),
                               float(rr.objectives[best]))
    assert rr.best.labels.shape[0] == blobs.shape[0]


# --------------------------------------------------------------------------
# Guard rails
# --------------------------------------------------------------------------

def test_fit_sharded_use_kernel_matches_single_device(blobs, c0, mesh8):
    """ISSUE 4: the sharded chunk layout streams through the dispatched
    kernel ops (the chunk mask rides the kernels' weight operand), where it
    used to raise NotImplementedError — full-mode parity with the unsharded
    kernel fit."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, chunks=4, stop_when_frozen=True, use_kernel=True))
    ref = eng.fit(blobs, c0, h_star=1e-4)
    res = eng.fit_sharded(blobs, c0, _data_mesh(mesh8), h_star=1e-4)
    assert int(res.n_iters) == int(ref.n_iters)
    np.testing.assert_allclose(res.params, ref.params, rtol=1e-4, atol=1e-4)
    assert float((res.labels == ref.labels).mean()) > 0.999


def test_fit_sharded_minibatch_use_kernel_matches_single_device(
        blobs, c0, mesh8):
    """Minibatch + kernel + shard_map: the replicated draw dynamic-slices
    the same global chunks on every shard and the psum'd kernel stats drive
    the paired stop — same trajectory as the unsharded kernel fit."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        stop_when_frozen=True, use_kernel=True, **MB))
    ref = eng.fit(blobs, c0, h_star=1e-4)
    res = eng.fit_sharded(blobs, c0, _data_mesh(mesh8), h_star=1e-4)
    assert int(res.n_iters) == int(ref.n_iters)
    np.testing.assert_allclose(res.params, ref.params, rtol=1e-4, atol=1e-4)


def test_sharded_restarts_use_kernel_parity(blobs, mesh8):
    """vmap-of-psum over per-chunk kernel calls inside shard_map: the
    restart fleet's custom_vmap routing survives the mesh."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, chunks=4, stop_when_frozen=True, use_kernel=True))
    params0 = eng.init_restarts(jax.random.PRNGKey(2), blobs, K, 3)
    ref = eng.fit_restarts(blobs, params0, h_star=1e-4)
    rr = eng.fit_restarts_sharded(blobs, params0, _data_mesh(mesh8),
                                  h_star=1e-4)
    assert int(rr.best_index) == int(ref.best_index)
    np.testing.assert_array_equal(np.asarray(rr.n_iters),
                                  np.asarray(ref.n_iters))
    np.testing.assert_allclose(rr.objectives, ref.objectives, rtol=1e-4)


def test_fit_sharded_needs_data_axis(blobs, c0, mesh8):
    mesh = jax.make_mesh((8,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    eng = ClusteringEngine("kmeans", EngineConfig(max_iters=10, chunks=4))
    with pytest.raises(ValueError, match="no data axis"):
        eng.fit_sharded(blobs, c0, mesh)


# --------------------------------------------------------------------------
# int8-EF compressed stats reductions (ISSUE 7): the sharded drivers with
# stats_compression="int8_ef" ride the ppermute ring + error feedback in
# the centred compression basis — the Eq. 7 stop must track the fp32 psum
# trajectory (the tentpole parity claim)
# --------------------------------------------------------------------------

MB_INT8 = dict(MB, stats_compression="int8_ef")


def test_sharded_int8_minibatch_kmeans_stop_parity(blobs, c0, mesh8):
    """int8 ring vs fp32 psum on the same sharded minibatch fit: identical
    stop iteration (the centred basis shrinks the quantisation error with
    the residual parameter motion, so h stays on the fp32 trajectory)."""
    ref = ClusteringEngine("kmeans", EngineConfig(**MB)).fit_sharded(
        blobs, c0, _data_mesh(mesh8), h_star=1e-3)
    res = ClusteringEngine("kmeans", EngineConfig(**MB_INT8)).fit_sharded(
        blobs, c0, _data_mesh(mesh8), h_star=1e-3)
    assert abs(int(res.n_iters) - int(ref.n_iters)) <= 1, \
        (int(res.n_iters), int(ref.n_iters))
    np.testing.assert_allclose(res.params, ref.params, rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(float(res.objective), float(ref.objective),
                               rtol=1e-2)
    assert float((res.labels == ref.labels).mean()) > 0.99


def test_sharded_int8_em_close(blobs, c0, mesh8):
    """EM's variance stats are the catastrophic-cancellation case the
    centred basis exists for: raw int8 second moments turn 1% wire error
    into ~80% variance error; centred, the fit stays within a couple of
    boundary iterations and the loglik matches to fp noise."""
    p0 = em_gmm.init_from_kmeans(blobs, c0)
    ref = ClusteringEngine("em", EngineConfig(**MB)).fit_sharded(
        blobs, p0, _data_mesh(mesh8), h_star=1e-3)
    res = ClusteringEngine("em", EngineConfig(**MB_INT8)).fit_sharded(
        blobs, p0, _data_mesh(mesh8), h_star=1e-3)
    assert abs(int(res.n_iters) - int(ref.n_iters)) <= 2, \
        (int(res.n_iters), int(ref.n_iters))
    np.testing.assert_allclose(float(res.objective), float(ref.objective),
                               rtol=1e-3)


def test_sharded_int8_restarts_best_agree(blobs, mesh8):
    """Per-restart EF state threads through the vmapped while_loop carry:
    the compressed fleet picks the same winner as the fp32 fleet."""
    eng = ClusteringEngine("kmeans", EngineConfig(**MB))
    eng8 = ClusteringEngine("kmeans", EngineConfig(**MB_INT8))
    params0 = eng.init_restarts(jax.random.PRNGKey(9), blobs, K, 4)
    ref = eng.fit_restarts_sharded(blobs, params0, _data_mesh(mesh8),
                                   h_star=1e-3)
    rr = eng8.fit_restarts_sharded(blobs, params0, _data_mesh(mesh8),
                                   h_star=1e-3)
    assert int(rr.best_index) == int(ref.best_index)
    np.testing.assert_allclose(rr.objectives, ref.objectives, rtol=1e-2)
    assert np.max(np.abs(np.asarray(rr.n_iters, np.int64)
                         - np.asarray(ref.n_iters, np.int64))) <= 2


def test_sharded_int8_full_mode_runs(blobs, c0, mesh8):
    """Full-sweep mode under compression: the whole-dataset stats ride the
    ring too (not just minibatch draws)."""
    cfg = EngineConfig(max_iters=100, chunks=4,
                       stats_compression="int8_ef")
    ref = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, chunks=4)).fit_sharded(
        blobs, c0, _data_mesh(mesh8), h_star=1e-3)
    res = ClusteringEngine("kmeans", cfg).fit_sharded(
        blobs, c0, _data_mesh(mesh8), h_star=1e-3)
    assert abs(int(res.n_iters) - int(ref.n_iters)) <= 1
    assert float((res.labels == ref.labels).mean()) > 0.99


def test_sharded_int8_wire_is_int8(mesh8):
    """The compiled reduction moves s8 through collective-permute — the
    compression must survive jit/while_loop staging, not silently promote
    back to f32 psum."""
    import re
    from functools import partial
    from jax import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.engine import _stats_reducer, get_algorithm

    alg = get_algorithm("kmeans")
    cfg = EngineConfig(axis_name="d", stats_axis_size=8,
                       stats_compression="int8_ef")
    init_ef, reduce_stats = _stats_reducer(alg, cfg)
    params = jnp.zeros((K, 3), jnp.float32)
    stats = alg.zero_stats(params)
    ef = init_ef(stats)

    def f(stats, ef):
        return reduce_stats(stats, ef, params)

    g = shard_map(f, mesh=mesh8,
                  in_specs=(jax.tree.map(lambda _: P(), stats),
                            jax.tree.map(lambda _: P(), ef)),
                  out_specs=(jax.tree.map(lambda _: P(), stats),
                             jax.tree.map(lambda _: P(), ef)),
                  check_vma=False)
    hlo = jax.jit(g).lower(stats, ef).compile().as_text()
    assert "collective-permute" in hlo
    assert re.search(r"s8\[[^\]]*\][^=\n]*collective-permute", hlo) \
        or re.search(r"collective-permute[^\n]*s8\[", hlo), \
        "no s8 collective-permute in compiled reduction"


def test_sharded_prefetch_bit_identical(blobs, c0, mesh8):
    """prefetch=True only reorders loads (same chunk order, same adds):
    the sharded fit must be bit-identical, full and minibatch."""
    for base in (dict(max_iters=60, chunks=4, stop_when_frozen=True), MB):
        a = ClusteringEngine("kmeans", EngineConfig(**base)).fit_sharded(
            blobs, c0, _data_mesh(mesh8), h_star=1e-4)
        b = ClusteringEngine("kmeans", EngineConfig(
            prefetch=True, **base)).fit_sharded(
            blobs, c0, _data_mesh(mesh8), h_star=1e-4)
        assert int(a.n_iters) == int(b.n_iters)
        np.testing.assert_array_equal(np.asarray(a.params),
                                      np.asarray(b.params))
        np.testing.assert_array_equal(np.asarray(a.labels),
                                      np.asarray(b.labels))


# --------------------------------------------------------------------------
# Trace harvesting under shard_map (ISSUE 5): psum'd stats make the
# recorded (J, h, params) history replicated and device-count invariant
# --------------------------------------------------------------------------

def test_sharded_trace_matches_single_device(blobs, c0, mesh8):
    eng = ClusteringEngine("kmeans", EngineConfig(stop_when_frozen=True,
                                                  trace=True, **MB))
    ref = eng.fit(blobs, c0, h_star=1e-4)
    res = eng.fit_sharded(blobs, c0, _data_mesh(mesh8), h_star=1e-4)
    n = int(ref.n_iters)
    assert int(res.n_iters) == n
    np.testing.assert_array_equal(np.asarray(res.trace.mask),
                                  np.asarray(ref.trace.mask))
    np.testing.assert_allclose(np.asarray(res.trace.objectives)[:n],
                               np.asarray(ref.trace.objectives)[:n],
                               rtol=1e-4)
    # h is a difference of nearly-equal J's over J: fp32 psum reduction
    # order shows up as absolute noise around 1e-6, so bound it absolutely
    np.testing.assert_allclose(np.asarray(res.trace.h)[:n],
                               np.asarray(ref.trace.h)[:n],
                               rtol=0.05, atol=2e-5)
    np.testing.assert_allclose(np.asarray(res.trace.params)[:n],
                               np.asarray(ref.trace.params)[:n],
                               rtol=1e-4, atol=1e-4)


def test_sharded_restart_traces_replicated(blobs, mesh8):
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=60, chunks=4, stop_when_frozen=True, trace=True))
    params0 = eng.init_restarts(jax.random.PRNGKey(3), blobs, K, 3)
    ref = eng.fit_restarts(blobs, params0, h_star=1e-4)
    rr = eng.fit_restarts_sharded(blobs, params0, _data_mesh(mesh8),
                                  h_star=1e-4)
    np.testing.assert_array_equal(np.asarray(rr.traces.mask.sum(axis=1),
                                             np.int32),
                                  np.asarray(rr.n_iters))
    np.testing.assert_allclose(np.asarray(rr.traces.objectives),
                               np.asarray(ref.traces.objectives),
                               rtol=1e-4, atol=1e-4)
