"""Serving: continuous batching correctness with unaligned prompts, plus
data-parallel prefill on the in-process 8-device mesh (conftest)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm, prefill, decode_step, init_cache
from repro.models.transformer import forward
from repro.serving import Server, Request

CFG = get_config("mistral-nemo-12b", reduced=True)
PARAMS = init_lm(jax.random.PRNGKey(0), CFG)


def _greedy_reference(prompt, n_new):
    """Autoregressive reference via full forward each step (exact)."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = forward(PARAMS, CFG,
                            tokens=jnp.asarray(toks, jnp.int32)[None])
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        toks.append(tok)
    return out


def test_server_matches_full_forward_reference():
    srv = Server(PARAMS, CFG, n_slots=2, max_seq=64)
    reqs = [Request(prompt=[3, 1, 4, 1, 5], max_new_tokens=6, rid=0),
            Request(prompt=[2, 7, 1], max_new_tokens=6, rid=1)]
    out = srv.generate(reqs)
    assert out[0] == _greedy_reference([3, 1, 4, 1, 5], 6)
    assert out[1] == _greedy_reference([2, 7, 1], 6)


def test_server_continuous_batching_refills_slots():
    srv = Server(PARAMS, CFG, n_slots=2, max_seq=64)
    reqs = [Request(prompt=[i + 1, i + 2], max_new_tokens=3 + i % 3, rid=i)
            for i in range(5)]
    out = srv.generate(reqs)
    assert set(out) == set(range(5))
    for i in range(5):
        assert len(out[i]) == 3 + i % 3
        # refilled slots must still match the exact reference
        assert out[i] == _greedy_reference([i + 1, i + 2], 3 + i % 3)


def test_decode_vector_positions_match_scalar():
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, CFG.vocab)
    _, caches = prefill(PARAMS, CFG, tokens=tokens[:, :s - 1])
    full = init_cache(CFG, b, s)
    caches = jax.tree.map(
        lambda d, src: jax.lax.dynamic_update_slice(
            d, src.astype(d.dtype), (0,) * src.ndim)
        if d.shape != src.shape else src.astype(d.dtype), full, caches)
    l_scalar, _ = decode_step(PARAMS, CFG, tokens[:, s - 1:s], caches, s - 1)
    l_vector, _ = decode_step(PARAMS, CFG, tokens[:, s - 1:s], caches,
                              jnp.full((b,), s - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(l_scalar, np.float32),
                               np.asarray(l_vector, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_forward_batch_sharded_matches_replicated(mesh8):
    """Prefill logits with the batch sharded over 8 devices == the
    single-device result — the serving batch axis is safe to scale out.
    Runs in-process on the session's forced host devices (no subprocess)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    toks = jax.random.randint(jax.random.PRNGKey(3), (8, 12), 0, CFG.vocab)
    ref, _ = forward(PARAMS, CFG, tokens=toks)
    sharded = jax.device_put(toks, NamedSharding(mesh8, P("d", None)))
    out, _ = jax.jit(lambda t: forward(PARAMS, CFG, tokens=t))(sharded)
    assert len(out.sharding.device_set) == 8
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_eos_stops_generation_and_is_stripped():
    srv = Server(PARAMS, CFG, n_slots=1, max_seq=64, eos_id=None)
    out = srv.generate([Request(prompt=[1, 2], max_new_tokens=4, rid=0)])
    eos = out[0][1]   # make the 2nd generated token the EOS
    srv2 = Server(PARAMS, CFG, n_slots=1, max_seq=64, eos_id=eos)
    out2 = srv2.generate([Request(prompt=[1, 2], max_new_tokens=4, rid=0)])
    # generation stops AT the first EOS and the EOS itself is not returned
    cut = out[0].index(eos)
    assert out2[0] == out[0][:cut]
    assert eos not in out2[0]


def test_single_token_request_returns_one_token():
    """max_new_tokens=1 must yield exactly one token (the old loop decoded
    once more before checking the length and returned two)."""
    srv = Server(PARAMS, CFG, n_slots=1, max_seq=64)
    out = srv.generate([Request(prompt=[1, 2, 3], max_new_tokens=1, rid=0)])
    assert out[0] == _greedy_reference([1, 2, 3], 1)


def test_admission_rejects_oversized_and_empty_prompts():
    srv = Server(PARAMS, CFG, n_slots=1, max_seq=8)
    with pytest.raises(ValueError, match="max_seq"):
        srv.generate([Request(prompt=list(range(8)), rid=7)])
    with pytest.raises(ValueError, match="empty prompt"):
        srv.generate([Request(prompt=[], rid=8)])
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.generate([Request(prompt=[1], max_new_tokens=0, rid=9)])
    # a bad request anywhere in the batch rejects before any device work
    with pytest.raises(ValueError, match="request 11"):
        srv.generate([Request(prompt=[1, 2], rid=10),
                      Request(prompt=list(range(99)), rid=11)])
