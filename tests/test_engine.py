"""ClusteringEngine: streaming-vs-monolithic parity, multi-restart vmap
equivalence, chunked kernel entry points, LongTailModel config routing,
the kmeans_fit_full frozen-only stop (ISSUE 1), minibatch mode (ISSUE 2):
tolerance parity with full-batch, the full-mode bit-identical regression
guard, config validation — and the kernel-dispatch composition (ISSUE 4):
fit_restarts / minibatch / both with use_kernel=True matching the jnp
trajectories."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import em_gmm
from repro.core.engine import ClusteringEngine, EngineConfig

K = 4


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(0)
    centers = np.array([[0, 0, 0], [8, 8, 8], [-8, 8, 0], [8, -8, 4]], float)
    x = np.concatenate([c + rng.normal(0, 1.0, (500, 3)) for c in centers])
    return jnp.asarray(x.astype(np.float32))   # N=2000: 4 | N, 7 ∤ N


@pytest.fixture(scope="module")
def c0(blobs):
    return core.kmeans_plus_plus_init(jax.random.PRNGKey(0), blobs, K)


# --------------------------------------------------------------------------
# Streaming parity — chunk counts that do and do not divide N
# --------------------------------------------------------------------------

@pytest.mark.parametrize("chunks", [1, 4, 7])
def test_streaming_parity_kmeans(blobs, c0, chunks):
    c_ref, l_ref, j_ref, it_ref = core.kmeans_fit_earlystop(
        blobs, c0, 1e-4, max_iters=100)
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, chunks=chunks, use_h_stop=True, stop_when_frozen=True))
    r = eng.fit(blobs, c0, h_star=1e-4)
    assert int(r.n_iters) == int(it_ref)
    np.testing.assert_allclose(r.params, c_ref, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(r.objective, j_ref, rtol=1e-5)
    # chunked fp error may flip the odd boundary point, nothing more
    assert float((r.labels == l_ref).mean()) > 0.999


@pytest.mark.parametrize("chunks", [1, 4, 7])
def test_streaming_parity_em(blobs, c0, chunks):
    p0 = em_gmm.init_from_kmeans(blobs, c0)
    p_ref, l_ref, ll_ref, it_ref = em_gmm.em_fit_earlystop(
        blobs, p0, 1e-5, max_iters=100)
    eng = ClusteringEngine("em", EngineConfig(max_iters=100, chunks=chunks))
    r = eng.fit(blobs, p0, h_star=1e-5)
    assert int(r.n_iters) == int(it_ref)
    np.testing.assert_allclose(r.params.means, p_ref.means,
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(r.params.var, p_ref.var, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(r.objective, ll_ref, rtol=1e-5)
    assert float((r.labels == l_ref).mean()) > 0.999


def test_streaming_wrapper_kwarg_matches_engine(blobs, c0):
    """The public drivers expose chunks= and agree with the engine."""
    c_a, _, j_a, it_a = core.kmeans_fit_earlystop(blobs, c0, 1e-4,
                                                  max_iters=100, chunks=5)
    c_b, _, j_b, it_b = core.kmeans_fit_earlystop(blobs, c0, 1e-4,
                                                  max_iters=100)
    assert int(it_a) == int(it_b)
    np.testing.assert_allclose(c_a, c_b, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(j_a, j_b, rtol=1e-5)


# --------------------------------------------------------------------------
# Multi-restart vmap with per-restart stop masks
# --------------------------------------------------------------------------

def test_multirestart_kmeans_matches_sequential(blobs):
    key = jax.random.PRNGKey(7)
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=60, use_h_stop=True, stop_when_frozen=True))
    seq = [eng.fit(blobs, core.kmeans_plus_plus_init(kk, blobs, K),
                   h_star=1e-4)
           for kk in jax.random.split(key, 3)]
    rr = eng.fit_restarts(blobs, key=key, k=K, restarts=3, h_star=1e-4)
    # seed-for-seed: same iteration counts and objectives per restart
    for i, s in enumerate(seq):
        assert int(rr.n_iters[i]) == int(s.n_iters), i
        np.testing.assert_allclose(rr.objectives[i], s.objective, rtol=1e-5)
    best_seq = int(np.argmin([float(s.objective) for s in seq]))
    assert int(rr.best_index) == best_seq
    np.testing.assert_allclose(rr.best.params, seq[best_seq].params,
                               rtol=1e-5, atol=1e-4)
    assert float((rr.best.labels == seq[best_seq].labels).mean()) > 0.999


def test_multirestart_em_matches_sequential(blobs):
    key = jax.random.PRNGKey(11)
    eng = ClusteringEngine("em", EngineConfig(max_iters=40))
    seq = [eng.fit(blobs, em_gmm.random_init(kk, blobs, K), h_star=1e-4)
           for kk in jax.random.split(key, 3)]
    rr = eng.fit_restarts(blobs, key=key, k=K, restarts=3, h_star=1e-4)
    for i, s in enumerate(seq):
        assert int(rr.n_iters[i]) == int(s.n_iters), i
        np.testing.assert_allclose(rr.objectives[i], s.objective,
                                   rtol=1e-4)
    best_seq = int(np.argmax([float(s.objective) for s in seq]))
    assert int(rr.best_index) == best_seq   # EM: argmax loglik


def test_multirestart_streaming_composes(blobs):
    """Both scale axes at once: vmapped restarts over chunked sweeps."""
    key = jax.random.PRNGKey(3)
    mono = ClusteringEngine("kmeans", EngineConfig(
        max_iters=60, stop_when_frozen=True))
    stream = ClusteringEngine("kmeans", EngineConfig(
        max_iters=60, chunks=7, stop_when_frozen=True))
    a = mono.fit_restarts(blobs, key=key, k=K, restarts=2, h_star=1e-4)
    b = stream.fit_restarts(blobs, key=key, k=K, restarts=2, h_star=1e-4)
    assert int(a.best_index) == int(b.best_index)
    np.testing.assert_array_equal(np.asarray(a.n_iters), np.asarray(b.n_iters))
    np.testing.assert_allclose(a.objectives, b.objectives, rtol=1e-5)


# --------------------------------------------------------------------------
# Chunked kernel entry points (fused contract, CPU interpret mode)
# --------------------------------------------------------------------------

def test_kmeans_assign_chunked_matches_monolithic(blobs, c0):
    from repro.kernels.kmeans_assign.ops import (kmeans_assign,
                                                 kmeans_assign_chunked)
    x = blobs[:777]                                    # 3 ∤ 777 remainder
    l1, s1, n1, j1 = kmeans_assign(x, c0)
    l2, s2, n2, j2 = kmeans_assign_chunked(x, c0, chunks=3)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(n1, n2, rtol=0)
    np.testing.assert_allclose(j1, j2, rtol=1e-5)


def test_gmm_estep_chunked_matches_monolithic(blobs, c0):
    from repro.kernels.gmm_estep.ops import gmm_estep, gmm_estep_chunked
    p = em_gmm.init_from_kmeans(blobs, c0)
    x = blobs[:777]
    o1 = gmm_estep(x, p.means, p.var, p.log_w)
    o2 = gmm_estep_chunked(x, p.means, p.var, p.log_w, chunks=3)
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))
    np.testing.assert_allclose(o1[1], o2[1], rtol=1e-5)
    for a, b in zip(o1[2:], o2[2:]):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-3)


def test_engine_kernel_streaming_path(blobs, c0):
    """use_kernel=True + chunks>1 routes through the chunked fused ops."""
    x = blobs[:512]
    ref = ClusteringEngine("kmeans", EngineConfig(
        max_iters=10, stop_when_frozen=True))
    ker = ClusteringEngine("kmeans", EngineConfig(
        max_iters=10, chunks=4, use_kernel=True, stop_when_frozen=True))
    a = ref.fit(x, c0, h_star=1e-4)
    b = ker.fit(x, c0, h_star=1e-4)
    assert int(a.n_iters) == int(b.n_iters)
    np.testing.assert_allclose(a.params, b.params, rtol=1e-4, atol=1e-3)


# --------------------------------------------------------------------------
# LongTailModel → EngineConfig routing
# --------------------------------------------------------------------------

def test_config_from_longtail(blobs, c0):
    res = core.kmeans_fit_traced(blobs, c0, max_iters=100)
    r, h = core.trace_to_rh(res, K)
    model = core.fit_longtail([(np.asarray(r), np.asarray(h))],
                              algorithm="kmeans", dataset="blobs",
                              family="quadratic")
    cfg = EngineConfig.from_longtail(model, 0.95, max_iters=100,
                                     stop_when_frozen=True)
    assert cfg.h_star == pytest.approx(model.threshold_for(0.95))
    eng = ClusteringEngine("kmeans", cfg)
    out = eng.fit(blobs, c0)                  # threshold comes from config
    _, _, _, it_ref = core.kmeans_fit_earlystop(
        blobs, c0, model.threshold_for(0.95), max_iters=100)
    assert int(out.n_iters) == int(it_ref)
    acc = float(core.rand_index(out.labels, res["labels"], K, K))
    assert acc >= 0.90


# --------------------------------------------------------------------------
# Minibatch mode (ISSUE 2)
# --------------------------------------------------------------------------

def test_minibatch_kmeans_reaches_full_batch_quality(blobs, c0):
    """B-of-C subsampled sweeps with 1/t learning-rate updates land within
    tolerance of the full-batch objective and partition while touching a
    quarter of the points per iteration."""
    full = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, stop_when_frozen=True))
    rf = full.fit(blobs, c0, h_star=1e-4)
    mb = ClusteringEngine("kmeans", EngineConfig(
        mode="minibatch", chunks=8, batch_chunks=2, patience=3,
        max_iters=300, stop_when_frozen=True))
    rm = mb.fit(blobs, c0, h_star=1e-4)
    np.testing.assert_allclose(float(rm.objective), float(rf.objective),
                               rtol=0.02)
    acc = float(core.rand_index(rm.labels, rf.labels, K, K))
    assert acc >= 0.99, acc
    # paired Eq. 7 h actually stops the loop (no run-to-max_iters)
    assert int(rm.n_iters) < 300


def test_minibatch_em_reaches_full_batch_quality(blobs, c0):
    p0 = em_gmm.init_from_kmeans(blobs, c0)
    full = ClusteringEngine("em", EngineConfig(max_iters=100))
    rf = full.fit(blobs, p0, h_star=1e-5)
    mb = ClusteringEngine("em", EngineConfig(
        mode="minibatch", chunks=8, batch_chunks=2, patience=3,
        max_iters=300))
    rm = mb.fit(blobs, p0, h_star=1e-4)
    # stepwise EM on subsampled responsibilities: per-point loglik within 1%
    np.testing.assert_allclose(float(rm.objective), float(rf.objective),
                               rtol=0.01)
    acc = float(core.rand_index(rm.labels, rf.labels, K, K))
    assert acc >= 0.95, acc


def test_minibatch_restarts_compose(blobs):
    """Minibatch × vmapped restarts: every restart draws its own chunk
    stream, stops on its own mask, and the best full-sweep objective wins."""
    mb = ClusteringEngine("kmeans", EngineConfig(
        mode="minibatch", chunks=8, batch_chunks=2, patience=3,
        max_iters=200, stop_when_frozen=True))
    rr = mb.fit_restarts(blobs, key=jax.random.PRNGKey(5), k=K, restarts=3,
                         h_star=1e-4)
    assert rr.objectives.shape == (3,)
    best = int(np.argmin(np.asarray(rr.objectives)))
    assert int(rr.best_index) == best
    np.testing.assert_allclose(float(rr.best.objective),
                               float(rr.objectives[best]))
    full = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, stop_when_frozen=True))
    rf = full.fit(blobs, core.kmeans_plus_plus_init(
        jax.random.PRNGKey(0), blobs, K), h_star=1e-4)
    np.testing.assert_allclose(float(rr.best.objective),
                               float(rf.objective), rtol=0.02)


def test_minibatch_reduces_points_touched_per_iteration(blobs, c0):
    """The compiled minibatch sweep really gathers B chunks, not all C —
    checked on the jaxpr-level shapes of the scan carry input."""
    from repro.core.engine import _minibatch_sweep, KMEANS
    cfg = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                       max_iters=10)
    xc, mask = core.chunk_points(blobs, 8)
    stats, n_batch = jax.jit(
        lambda p, k: _minibatch_sweep(KMEANS, cfg, xc, mask, p, k)
    )(c0, jax.random.PRNGKey(0))
    assert float(n_batch) == pytest.approx(2 * mask.shape[1])
    assert float(n_batch) <= 0.26 * blobs.shape[0]


def test_minibatch_too_few_effective_chunks_fails_loud():
    """chunk_points clamps C to the row count; a tiny x must hit the
    engine's message, not choice(replace=False)'s trace error."""
    tiny = jnp.asarray(np.arange(20.0).reshape(10, 2), jnp.float32)
    eng = ClusteringEngine("kmeans", EngineConfig(
        mode="minibatch", chunks=64, batch_chunks=16, max_iters=5))
    c0 = jnp.asarray([[0.0, 1.0], [18.0, 19.0]], jnp.float32)
    with pytest.raises(ValueError, match="effective chunks"):
        eng.fit(tiny, c0)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="chunks >= 2"):
        EngineConfig(mode="minibatch")
    with pytest.raises(ValueError, match="batch_chunks < chunks"):
        EngineConfig(mode="minibatch", chunks=8, batch_chunks=8)
    with pytest.raises(ValueError, match="unknown engine mode"):
        EngineConfig(mode="online")
    with pytest.raises(ValueError, match="decay"):
        EngineConfig(mode="minibatch", chunks=8, batch_chunks=2, decay=0.0)
    # minibatch + use_kernel is a supported combination since ISSUE 4
    EngineConfig(mode="minibatch", chunks=8, batch_chunks=2, use_kernel=True)
    # auto/None resolve to a concrete registry name at construction (so
    # the static config — and hence the jit cache key — carries it)
    cfg = EngineConfig(use_kernel=True)
    assert cfg.kernel_backend not in (None, "auto")
    if not os.environ.get("REPRO_FORCE_KERNEL_BACKEND"):
        with pytest.raises(ValueError, match="use_kernel=False"):
            EngineConfig(kernel_backend="interpret")


def test_engine_config_compression_validation():
    """ISSUE 7: the stats_compression knobs fail loud on every unusable
    combination instead of silently running uncompressed (or deadlocking
    a frozen-centroid stop that can never fire)."""
    with pytest.raises(ValueError, match="unknown stats_compression"):
        EngineConfig(stats_compression="fp8")
    with pytest.raises(ValueError, match="no effect"):
        EngineConfig(stats_axis_size=8)       # stray knob without int8_ef
    with pytest.raises(ValueError, match="stop_when_frozen"):
        EngineConfig(stats_compression="int8_ef", stop_when_frozen=True)
    with pytest.raises(ValueError, match="single-axis"):
        EngineConfig(stats_compression="int8_ef",
                     axis_name=("pod", "data"))
    with pytest.raises(ValueError, match="stats_axis_size"):
        EngineConfig(stats_compression="int8_ef", axis_name="data")
    # the combinations the sharded drivers build are valid
    EngineConfig(stats_compression="int8_ef")
    EngineConfig(stats_compression="int8_ef", axis_name="data",
                 stats_axis_size=8)


def test_prefetch_bit_identical_single_device(blobs, c0):
    """prefetch=True double-buffers the chunk scan without changing chunk
    order or accumulation: bit-identical fits, full-streaming and
    minibatch."""
    for base in (dict(max_iters=60, chunks=4, stop_when_frozen=True),
                 dict(mode="minibatch", chunks=8, batch_chunks=2,
                      patience=3, max_iters=120, seed=11,
                      stop_when_frozen=True)):
        a = ClusteringEngine("kmeans", EngineConfig(**base)).fit(
            blobs, c0, h_star=1e-4)
        b = ClusteringEngine("kmeans", EngineConfig(
            prefetch=True, **base)).fit(blobs, c0, h_star=1e-4)
        assert int(a.n_iters) == int(b.n_iters)
        np.testing.assert_array_equal(np.asarray(a.params),
                                      np.asarray(b.params))
        np.testing.assert_array_equal(np.asarray(a.labels),
                                      np.asarray(b.labels))


def test_stats_wire_bytes_leaf_policy():
    """Analytic bytes mirror the reducer's leaf policy: int8 moves
    1 byte/element + one f32 scale per array leaf, scalar leaves stay f32,
    and the ≥3× fp32/int8 ratio the artifact gates holds at k=8, d=8."""
    from repro.core.engine import get_algorithm, stats_wire_bytes
    params = jnp.zeros((8, 8), jnp.float32)
    stats = get_algorithm("kmeans").zero_stats(params)
    fp32 = stats_wire_bytes(stats, 8, "none")
    int8 = stats_wire_bytes(stats, 8, "int8_ef")
    # payloads before the ring factor: (64+8+1)·4 = 292 B vs
    # (64+8)·1 + 2·4 scales + 4 (scalar J) = 84 B
    assert fp32 == (2 * 7 * 292) // 8 == 511
    assert int8 == (2 * 7 * 84) // 8 == 147
    assert fp32 / int8 >= 3.0
    assert stats_wire_bytes(stats, 1, "int8_ef") == 0   # no ring, no wire


def test_engine_config_unregistered_backend_fails_at_dispatch(blobs, c0):
    """Custom register_backend() names are legal in the config; a name no
    op registered fails loud at the first dispatch with the available
    list, not at construction."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=5, use_kernel=True, kernel_backend="mosaic"))
    with pytest.raises(NotImplementedError, match="no 'mosaic' backend"):
        eng.fit(blobs, c0)


def test_full_mode_rejects_minibatch_only_knobs():
    """mode='full' used to silently ignore batch_chunks/decay/seed/ema, so
    a CLI typo like --batch-chunks without --mode minibatch ran a plain
    full-sweep fit while looking like a minibatch run.  Fail loud instead
    (ISSUE 3 satellite)."""
    for kw in ({"batch_chunks": 3}, {"decay": 0.5}, {"seed": 7},
               {"ema": 0.5}):
        with pytest.raises(ValueError, match="minibatch-only"):
            EngineConfig(**kw)
    with pytest.raises(ValueError, match="minibatch-only"):
        EngineConfig(mode="full", batch_chunks=3, decay=0.5, seed=7)
    EngineConfig()                        # defaults stay valid
    EngineConfig(chunks=8)                # streaming-only full mode too


def test_fit_restarts_use_kernel_matches_xla_path(blobs):
    """ISSUE 4: the vmapped multi-restart driver routes through the kernels'
    restart grid axis (custom_vmap rule) — seed-for-seed parity with the
    non-kernel fleet, where it used to raise NotImplementedError."""
    key = jax.random.PRNGKey(7)
    ref = ClusteringEngine("kmeans", EngineConfig(
        max_iters=60, stop_when_frozen=True))
    ker = ClusteringEngine("kmeans", EngineConfig(
        max_iters=60, stop_when_frozen=True, use_kernel=True))
    a = ref.fit_restarts(blobs, key=key, k=K, restarts=3, h_star=1e-4)
    b = ker.fit_restarts(blobs, key=key, k=K, restarts=3, h_star=1e-4)
    assert int(a.best_index) == int(b.best_index)
    np.testing.assert_array_equal(np.asarray(a.n_iters),
                                  np.asarray(b.n_iters))
    np.testing.assert_allclose(a.objectives, b.objectives, rtol=1e-4)
    np.testing.assert_allclose(a.best.params, b.best.params,
                               rtol=1e-4, atol=1e-3)
    assert float((a.best.labels == b.best.labels).mean()) > 0.999


def test_minibatch_use_kernel_matches_xla_path(blobs, c0):
    """ISSUE 4: mode='minibatch' composes with use_kernel=True via the
    gather-free statically-sliced subsample driver — identical stop
    iteration and params (within fp32 tolerance) to the jnp path, where it
    used to raise NotImplementedError at config time."""
    kw = dict(mode="minibatch", chunks=8, batch_chunks=2, patience=3,
              max_iters=300, stop_when_frozen=True)
    rx = ClusteringEngine("kmeans", EngineConfig(**kw)).fit(
        blobs, c0, h_star=1e-4)
    rk = ClusteringEngine("kmeans", EngineConfig(use_kernel=True, **kw)).fit(
        blobs, c0, h_star=1e-4)
    assert int(rk.n_iters) == int(rx.n_iters)
    np.testing.assert_allclose(rk.params, rx.params, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(rk.objective), float(rx.objective),
                               rtol=1e-5)


def test_minibatch_restarts_use_kernel_compose(blobs):
    """Both new kernel axes at once: per-restart minibatch draws dynamic-
    slice per-restart chunks (batched points AND batched params on the
    kernels' restart grid)."""
    kw = dict(mode="minibatch", chunks=8, batch_chunks=2, patience=3,
              max_iters=200, stop_when_frozen=True)
    key = jax.random.PRNGKey(5)
    a = ClusteringEngine("kmeans", EngineConfig(**kw)).fit_restarts(
        blobs, key=key, k=K, restarts=3, h_star=1e-4)
    b = ClusteringEngine("kmeans", EngineConfig(
        use_kernel=True, **kw)).fit_restarts(
        blobs, key=key, k=K, restarts=3, h_star=1e-4)
    assert int(a.best_index) == int(b.best_index)
    np.testing.assert_array_equal(np.asarray(a.n_iters),
                                  np.asarray(b.n_iters))
    np.testing.assert_allclose(a.objectives, b.objectives, rtol=1e-4)


# --------------------------------------------------------------------------
# mode="full" is bit-identical to the pre-PR engine (regression guard)
# --------------------------------------------------------------------------

# Goldens recorded from the engine at 7a77552 (pre-minibatch), CPU f32.
_GOLD_KM_ITERS = 2
_GOLD_KM_J = 3033.8115234375
_GOLD_EM_ITERS = 6
_GOLD_EM_LL = -5653.07080078125


def _golden_blobs():
    rng = np.random.default_rng(42)
    centers = np.array([[0, 0, 0], [8, 8, 8], [-8, 8, 0], [8, -8, 4]], float)
    x = np.concatenate([c + rng.normal(0, 1.0, (250, 3)) for c in centers])
    return jnp.asarray(x.astype(np.float32))


@pytest.mark.skipif(bool(os.environ.get("REPRO_FORCE_KERNEL_BACKEND")),
                    reason="goldens pin the jnp sweep's fp32 reduction "
                           "order; the forced kernel path accumulates "
                           "block-wise")
def test_full_mode_matches_pre_minibatch_goldens():
    """Adding mode/batch_chunks/decay/seed/ema to the engine state must not
    perturb the full-batch path: same iteration counts and (to fp32 ulp)
    the same objectives as the pre-PR engine on a pinned input."""
    x = _golden_blobs()
    c0 = jnp.asarray([[1., 1., 1.], [7., 7., 7.],
                      [-7., 7., 0.], [7., -7., 3.]], jnp.float32)
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=100, use_h_stop=True, stop_when_frozen=True))
    r = eng.fit(x, c0, h_star=1e-4)
    assert int(r.n_iters) == _GOLD_KM_ITERS
    np.testing.assert_allclose(float(r.objective), _GOLD_KM_J, rtol=1e-6)

    p0 = em_gmm.init_from_kmeans(x, c0)
    enge = ClusteringEngine("em", EngineConfig(max_iters=60))
    re_ = enge.fit(x, p0, h_star=1e-5)
    assert int(re_.n_iters) == _GOLD_EM_ITERS
    np.testing.assert_allclose(float(re_.objective), _GOLD_EM_LL, rtol=1e-6)


# --------------------------------------------------------------------------
# kmeans_fit_full: stop only when the centroids freeze (regression)
# --------------------------------------------------------------------------

def test_kmeans_full_runs_until_frozen():
    """fp32 J plateaus bit-for-bit (ΔJ < ulp(J) with J ~ N·B²) while the
    cluster boundary is still sweeping; the old h*=0/patience=1 stop quit on
    the plateau and returned a non-fixed-point.  Pin the fix: fit_full must
    land on a true Lloyd fixed point."""
    b = 1e4
    base = np.arange(40.0)
    x = np.concatenate([np.stack([base, np.full(40, b)], 1),
                        np.stack([base, np.full(40, -b)], 1)])
    xj = jnp.asarray(x.astype(np.float32))
    c0 = jnp.asarray([[0.0, 0.0], [1.0, 0.0]], jnp.float32)

    # the plateau is real: the h-based path stops while centroids still move
    c_h, _, _, it_h = core.kmeans_fit_earlystop(xj, c0, 0.0, max_iters=500)
    c_h2, _, _ = core.kmeans_step(xj, c_h)
    assert not bool(jnp.all(c_h2 == c_h)), \
        "plateau scenario lost its teeth — rebuild the dataset"

    c_f, _, _, it_f = core.kmeans_fit_full(xj, c0, max_iters=500)
    c_f2, _, _ = core.kmeans_step(xj, c_f)
    assert bool(jnp.all(c_f2 == c_f)), "fit_full returned a non-fixed-point"
    assert int(it_f) > int(it_h)
    assert int(it_f) < 500                    # still terminates by freezing
