"""Rand index: contingency identity vs the paper's O(n²) pair formulation +
property-based invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import rand_index, adjusted_rand_index, contingency_table
from repro.core.rand_index import rand_index_pairwise_reference


labels = st.integers(0, 5)


@given(st.lists(st.tuples(labels, labels), min_size=2, max_size=120))
@settings(max_examples=60, deadline=None)
def test_matches_pairwise_oracle(pairs):
    a = np.array([p[0] for p in pairs])
    b = np.array([p[1] for p in pairs])
    fast = float(rand_index(jnp.asarray(a), jnp.asarray(b), 6, 6))
    slow = rand_index_pairwise_reference(a, b)
    assert fast == pytest.approx(slow, abs=1e-5)


@given(st.lists(labels, min_size=2, max_size=80))
@settings(max_examples=40, deadline=None)
def test_identical_partitions_are_one(xs):
    a = jnp.asarray(np.array(xs))
    assert float(rand_index(a, a, 6, 6)) == pytest.approx(1.0)


@given(st.lists(st.tuples(labels, labels), min_size=2, max_size=80),
       st.permutations(list(range(6))))
@settings(max_examples=40, deadline=None)
def test_label_permutation_invariance(pairs, perm):
    """Rand depends on the partition, not the label names."""
    a = np.array([p[0] for p in pairs])
    b = np.array([p[1] for p in pairs])
    b_renamed = np.array(perm)[b]
    r1 = float(rand_index(jnp.asarray(a), jnp.asarray(b), 6, 6))
    r2 = float(rand_index(jnp.asarray(a), jnp.asarray(b_renamed), 6, 6))
    assert r1 == pytest.approx(r2, abs=1e-6)


@given(st.lists(st.tuples(labels, labels), min_size=2, max_size=80))
@settings(max_examples=40, deadline=None)
def test_range_and_symmetry(pairs):
    a = np.array([p[0] for p in pairs])
    b = np.array([p[1] for p in pairs])
    r_ab = float(rand_index(jnp.asarray(a), jnp.asarray(b), 6, 6))
    r_ba = float(rand_index(jnp.asarray(b), jnp.asarray(a), 6, 6))
    assert 0.0 <= r_ab <= 1.0 + 1e-6
    assert r_ab == pytest.approx(r_ba, abs=1e-6)


def test_paper_worked_example():
    """Fig. 1: Rand(P1, P2) = (5 + 22) / 36 = 75%."""
    p1 = np.array([0, 0, 0, 0, 1, 1, 1, 2, 2])     # a1..a9 in P1
    p2 = np.array([0, 0, 0, 1, 1, 1, 2, 2, 2])     # a1..a9 in P2
    r = float(rand_index(jnp.asarray(p1), jnp.asarray(p2), 3, 3))
    assert r == pytest.approx(27.0 / 36.0)


def test_exact_rand_at_2_20_points():
    """Regression (ISSUE 6): at N = 2^20 the per-cell pair counts C(N_ij, 2)
    exceed float32's exact-integer range (2^24), so the old float32 comb2
    silently rounded.  The host path must produce the exactly-known answer
    computed with arbitrary-precision integers."""
    n, k = 1 << 20, 4
    rng = np.random.default_rng(42)
    a = rng.integers(0, k, n)
    b = a.copy()
    flip = rng.choice(n, n // 64, replace=False)
    b[flip] = (b[flip] + 1 + rng.integers(0, k - 1, flip.size)) % k

    # arbitrary-precision oracle from a numpy-built contingency table
    table = np.zeros((k, k), np.int64)
    np.add.at(table, (a, b), 1)

    def c2(x):
        return int(x) * (int(x) - 1) // 2

    total = c2(n)
    n11 = sum(c2(v) for v in table.ravel())
    same_a = sum(c2(v) for v in table.sum(axis=1))
    same_b = sum(c2(v) for v in table.sum(axis=0))
    expected = (n11 + total - same_a - same_b + n11) / total

    got = rand_index(jnp.asarray(a), jnp.asarray(b), k, k)
    assert float(got) == expected                 # bit-exact, no approx

    # the streamed path must agree with itself across chunk boundaries
    from repro.core.rand_index import contingency_table_exact
    t_stream = contingency_table_exact(a, b, k, k, chunk_rows=100_003)
    np.testing.assert_array_equal(t_stream, table)


def test_exact_path_handles_counts_beyond_float64_exact_range():
    """Synthetic contingency table at beyond-paper scale: cell counts of
    2^32 make C(n,2) ≈ 8.6e18 > 2^63 − 1 for the n·(n−1) intermediate —
    only arbitrary-precision host math survives.  Rand of a diagonal table
    plus an off-diagonal speck is exactly computable by hand."""
    from repro.core.rand_index import rand_index_from_contingency
    big = 1 << 32
    table = np.array([[big, 1], [0, big]], dtype=np.int64)

    def c2(x):
        return x * (x - 1) // 2

    n = 2 * big + 1
    total = c2(n)
    n11 = 2 * c2(big)
    same_a = c2(big + 1) + c2(big)
    same_b = c2(big) + c2(big + 1)
    expected = (n11 + total - same_a - same_b + n11) / total
    assert float(rand_index_from_contingency(table)) == expected


def test_contingency_totals():
    a = np.array([0, 0, 1, 2, 1])
    b = np.array([1, 1, 0, 0, 1])
    t = np.asarray(contingency_table(jnp.asarray(a), jnp.asarray(b), 3, 2))
    assert t.sum() == 5
    assert t[0, 1] == 2 and t[1, 0] == 1 and t[1, 1] == 1 and t[2, 0] == 1


def test_ari_chance_corrected():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 4, 2000)
    b = rng.integers(0, 4, 2000)
    ari = float(adjusted_rand_index(jnp.asarray(a), jnp.asarray(b), 4, 4))
    assert abs(ari) < 0.05          # independent labelings → ≈ 0
    assert float(adjusted_rand_index(jnp.asarray(a), jnp.asarray(a), 4, 4)) \
        == pytest.approx(1.0)
