"""Mode-matched long-tail training (ISSUE 5): engine trace invariants,
configuration-matched fits, and the provenance contract."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.earlystop import change_rate
from repro.core.engine import ClusteringEngine, EngineConfig
from repro.core.longtail_train import (TrainingPlan, config_fingerprint,
                                       fit_for_config, harvest_config,
                                       harvest_traces, reference_config,
                                       reference_partition,
                                       engine_trace_to_rh)


def _blobs(n=3000, d=4, k=3, seed=0, spread=6.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, spread, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.0, (n // k, d)) for c in centers])
    return jnp.asarray(x[rng.permutation(len(x))].astype(np.float32))


@pytest.fixture(scope="module")
def blobs():
    return _blobs()


# --------------------------------------------------------------------------
# Trace invariants (the new fit-driver return contract)
# --------------------------------------------------------------------------

def test_full_mode_h_matches_change_rate_recomputed_from_j(blobs):
    """Harvested h_i must equal earlystop.change_rate applied to the
    recorded J trace — the trace is the Eq. 7 source of truth."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=50, trace=True, use_h_stop=False, stop_when_frozen=True))
    res = eng.fit(blobs, eng.init(jax.random.PRNGKey(0), blobs, 3))
    tr = res.trace
    n = int(res.n_iters)
    assert n >= 2 and float(tr.mask.sum()) == n
    js = np.asarray(tr.objectives)
    h = np.asarray(tr.h)
    rec = np.asarray(change_rate(jnp.asarray(js[1:n]), jnp.asarray(js[:n - 1])))
    np.testing.assert_allclose(h[1:n], rec, rtol=1e-6)
    assert np.isinf(h[0])                       # Eq. 7 starts at i = 2
    assert np.all(tr.mask[n:] == 0)             # nothing recorded past stop


def test_minibatch_paired_h_finite_and_nonnegative(blobs):
    eng = ClusteringEngine("kmeans", EngineConfig(
        mode="minibatch", chunks=8, batch_chunks=2, patience=5,
        max_iters=60, trace=True))
    res = eng.fit(blobs, eng.init(jax.random.PRNGKey(1), blobs, 3),
                  h_star=1e-5)
    n = int(res.n_iters)
    h = np.asarray(res.trace.h)[:n]
    assert n >= 1
    assert np.all(np.isfinite(h)), h            # paired from step one
    assert np.all(h >= 0.0), h


def test_minibatch_unpaired_trace_keeps_measured_at_invariant(blobs):
    """With the h predicate off, minibatch skips the paired pass: the trace
    must record the PRE-update params (where the subsample objective was
    measured) and leave h at inf — there is no Eq. 7 signal to fake."""
    cfg = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                       max_iters=5, use_h_stop=False, trace=True, seed=3)
    eng = ClusteringEngine("kmeans", cfg)
    c0 = eng.init(jax.random.PRNGKey(4), blobs, 3)
    res = eng.fit(blobs, c0)
    tr = res.trace
    assert np.all(np.isinf(np.asarray(tr.h)[:5]))
    # index 0 holds the objective/params measured BEFORE the first update:
    # the recorded params must equal the initial centroids
    np.testing.assert_allclose(np.asarray(tr.params)[0], np.asarray(c0),
                               rtol=1e-6)
    # and harvesting yields an empty cloud rather than garbage pairs
    r, h = engine_trace_to_rh(tr, blobs, algorithm="kmeans", k=3)
    assert r.size == 0 and h.size == 0


def test_restart_traces_cover_every_restart(blobs):
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=40, trace=True, use_h_stop=False, stop_when_frozen=True))
    rr = eng.fit_restarts(blobs, key=jax.random.PRNGKey(2), k=3, restarts=4)
    tr = rr.traces
    assert tr.objectives.shape[0] == 4
    # each restart's mask counts exactly its own iterations
    np.testing.assert_array_equal(np.asarray(tr.mask.sum(axis=1), np.int32),
                                  np.asarray(rr.n_iters))
    # stopped restarts stay frozen: no writes beyond their own n_iters
    for ri in range(4):
        n = int(rr.n_iters[ri])
        assert np.all(np.asarray(tr.mask)[ri, n:] == 0)


def test_trace_off_by_default(blobs):
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=10, use_h_stop=False, stop_when_frozen=True))
    assert eng.fit(blobs, eng.init(jax.random.PRNGKey(0), blobs, 3)).trace \
        is None
    assert eng.fit_restarts(blobs, key=jax.random.PRNGKey(0), k=3,
                            restarts=2).traces is None


def test_trace_to_rh_accuracy_is_rand_against_final(blobs):
    """r_i from the recorded parameter trajectory must end at 1 (the final
    partition against itself) and stay within [0, 1]."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=50, trace=True, use_h_stop=False, stop_when_frozen=True))
    res = eng.fit(blobs, eng.init(jax.random.PRNGKey(3), blobs, 3))
    r, h = engine_trace_to_rh(res.trace, blobs, algorithm="kmeans", k=3)
    assert r.shape == h.shape and r.size >= 1
    assert np.all((r >= 0.0) & (r <= 1.0))
    assert r[-1] == pytest.approx(1.0)
    assert np.all(np.isfinite(h))


def test_trace_to_rh_accepts_explicit_reference(blobs):
    """ref_labels replaces the self-reference: against the true final
    partition r ends at 1; against a shuffled partition it must not."""
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=50, trace=True, use_h_stop=False, stop_when_frozen=True))
    res = eng.fit(blobs, eng.init(jax.random.PRNGKey(3), blobs, 3))
    r_self, _ = engine_trace_to_rh(res.trace, blobs, algorithm="kmeans", k=3)
    r_ref, _ = engine_trace_to_rh(res.trace, blobs, algorithm="kmeans", k=3,
                                  ref_labels=np.asarray(res.labels))
    np.testing.assert_allclose(r_ref, r_self, rtol=1e-6)
    perm = np.random.default_rng(0).permutation(np.asarray(res.labels))
    r_bad, _ = engine_trace_to_rh(res.trace, blobs, algorithm="kmeans", k=3,
                                  ref_labels=perm)
    assert r_bad[-1] < 0.99


def test_minibatch_harvest_measures_r_against_fullbatch_reference(blobs):
    """ROADMAP carry-over: the minibatch harvest's r must be computed
    against the group's full-batch partition, not the trace's own
    subsample endpoint — harvest_traces output must match an explicit
    reference_partition recomputation, not the self-referenced pairs."""
    hard = _blobs(seed=1, spread=1.5)   # overlapping clusters: minibatch
    prod = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                        patience=3, max_iters=60)
    plan = TrainingPlan(algorithm="kmeans", k=3, config=prod, seed=0)
    (r, h), = harvest_traces(plan, np.asarray(hard)[None])
    # recompute by hand: same harvest run, explicit full-batch reference
    cfg = harvest_config(prod, "kmeans", seed=plan.seed)
    eng = ClusteringEngine("kmeans", cfg)
    key = jax.random.PRNGKey(plan.seed)
    c0 = eng.init(key, hard, 3)
    ref = reference_partition(plan, hard, c0)
    res = eng.fit(hard, c0)
    r_ref, h_ref = engine_trace_to_rh(res.trace, hard, algorithm="kmeans",
                                      k=3, ref_labels=ref)
    np.testing.assert_allclose(r, r_ref, rtol=1e-6)
    np.testing.assert_allclose(h, h_ref, rtol=1e-6)
    r_self, _ = engine_trace_to_rh(res.trace, hard, algorithm="kmeans", k=3)
    # self-reference was the bug: it pins the endpoint at r = 1 even though
    # the subsample endpoint is NOT the full-batch partition
    assert r_self[-1] == pytest.approx(1.0)
    assert not np.allclose(r, r_self)


def test_reference_config_resets_minibatch_regime():
    prod = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                        decay=0.9, ema=0.5, patience=4, max_iters=60,
                        seed=9)
    ref = reference_config(prod, "kmeans")
    assert ref.mode == "full" and ref.batch_chunks == 0
    assert ref.decay == 1.0 and ref.seed == 0 and ref.ema == 0.0
    assert ref.stop_when_frozen and not ref.use_h_stop and not ref.trace
    assert ref.chunks == prod.chunks    # memory layout is kept


# --------------------------------------------------------------------------
# Matched fits
# --------------------------------------------------------------------------

def test_matched_fit_threshold_monotone_in_rstar(blobs):
    groups = np.stack([np.asarray(_blobs(seed=s)) for s in range(3)])
    prod = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                        patience=5, max_iters=80)
    model = fit_for_config(TrainingPlan(algorithm="kmeans", k=3, config=prod,
                                        family="quadratic"), groups)
    ths = [model.threshold_for(a)
           for a in (0.80, 0.90, 0.95, 0.99, 0.999)]
    assert all(a >= b - 1e-15 for a, b in zip(ths, ths[1:])), ths
    assert ths[-1] > 0                           # floored, never <= 0


def test_em_harvest_traces(blobs):
    traces = harvest_traces(TrainingPlan(
        algorithm="em", k=3, config=EngineConfig(max_iters=40)),
        np.asarray(blobs)[None])
    (r, h), = traces
    assert r.size >= 1
    assert np.all(np.isfinite(h)) and np.all(h >= 0)
    assert np.all((r >= 0) & (r <= 1))


def test_restart_plan_harvests_r_traces_per_group(blobs):
    traces = harvest_traces(TrainingPlan(
        algorithm="kmeans", k=3, config=EngineConfig(max_iters=40),
        restarts=3), np.asarray(blobs)[None])
    assert len(traces) == 3                      # one trace per restart


# --------------------------------------------------------------------------
# Provenance contract
# --------------------------------------------------------------------------

def test_config_mismatch_warning_fires(blobs):
    prod = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                        patience=5, max_iters=60)
    model = fit_for_config(TrainingPlan(algorithm="kmeans", k=3, config=prod,
                                        family="quadratic"),
                           np.asarray(blobs)[None])
    assert model.engine_config["mode"] == "minibatch"
    with pytest.warns(UserWarning, match="mode-matched"):
        EngineConfig.from_longtail(model, 0.95, max_iters=60)  # full mode
    # serving the stamped regime is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        EngineConfig.from_longtail(model, 0.95, mode="minibatch", chunks=8,
                                   batch_chunks=2, patience=5, max_iters=60,
                                   seed=7)


def test_legacy_model_without_provenance_never_warns():
    r = np.linspace(0.3, 1.0, 50)
    h = 1.8 - 3.6 * r + 1.8 * r * r
    model = core.fit_longtail([(r, h)], algorithm="kmeans", dataset="t",
                              family="quadratic")
    assert model.engine_config is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        EngineConfig.from_longtail(model, 0.95, max_iters=10)


def test_provenance_json_roundtrip(blobs):
    prod = EngineConfig(mode="minibatch", chunks=4, batch_chunks=1,
                        decay=0.9, max_iters=40)
    model = fit_for_config(TrainingPlan(algorithm="kmeans", k=3, config=prod,
                                        family="quadratic"),
                           np.asarray(blobs)[None])
    again = core.LongTailModel.from_json(model.to_json())
    assert again.engine_config == model.engine_config
    assert again.engine_config == config_fingerprint(prod)


def test_harvest_config_keeps_regime_reaims_stop():
    prod = EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                        decay=0.9, ema=0.5, patience=2, max_iters=60,
                        h_star=1e-3, stop_when_frozen=True)
    hc = harvest_config(prod, "kmeans", seed=5)
    assert hc.trace and hc.h_star == 0.0 and not hc.stop_when_frozen
    assert hc.seed == 5 and hc.patience >= 3
    for f in ("mode", "chunks", "batch_chunks", "decay", "ema",
              "use_kernel", "kernel_backend"):
        assert getattr(hc, f) == getattr(prod, f), f
    # full-mode kmeans: frozen-centroid stop, no h predicate (fp32 J
    # plateaus must not end the harvest before the Lloyd fixed point)
    hk = harvest_config(EngineConfig(max_iters=60), "kmeans")
    assert hk.trace and not hk.use_h_stop and hk.stop_when_frozen
    # full-mode EM: tolerance stop
    he = harvest_config(EngineConfig(max_iters=60), "em")
    assert he.use_h_stop and 0 < he.h_star <= 1e-10
