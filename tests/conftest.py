"""Test substrate: in-process multi-device session + optional hypothesis.

**Multi-device.** The whole test session runs with 8 XLA host-platform
devices: the flag is appended to ``XLA_FLAGS`` below, *before* anything can
import jax (pytest loads conftest first; the backend reads the flag at its
lazy first initialisation).  The session-scoped ``mesh8`` fixture hands
tests a real 8-device ``("d",)`` mesh, so multi-device paths (shard_map
collectives, GSPMD lowering, sharded restore) run in-process instead of
behind ``subprocess.run`` — same coverage, one process, debuggable.  An
externally-set device-count flag wins (that is how CI pins the single- and
multi-device legs); tests needing the mesh skip when fewer than 8 devices
exist.  Single-device numerics are unchanged: computations still place onto
device 0 unless a test shards them explicitly.

**Hypothesis is optional.** The property-based suites (test_kernels,
test_rand_index, test_regression, test_earlystop_and_cost, test_invariants)
are written against the real hypothesis API.  On a bare JAX install this
conftest registers a minimal, deterministic stand-in *before collection*:
``@given`` becomes a seeded random sweep of ``max_examples`` draws (no
shrinking, fixed seed), which keeps every property executed — just with
fewer, reproducible examples.  Install ``requirements-dev.txt`` to run the
full hypothesis engine instead; this module then does nothing.

In the same spirit, importing ``repro.compat`` first installs jax
forward-compat shims (jax.shard_map / AxisType / make_mesh(axis_types=))
for older jaxlib builds.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

_DEVCOUNT_FLAG = "--xla_force_host_platform_device_count"
# stash what the user actually set, so tests that spawn CLI subprocesses
# (the test_system smoke tests) can hand them the stock environment
ORIG_XLA_FLAGS = os.environ.get("XLA_FLAGS", "")
if _DEVCOUNT_FLAG not in ORIG_XLA_FLAGS:
    os.environ["XLA_FLAGS"] = (ORIG_XLA_FLAGS + f" {_DEVCOUNT_FLAG}=8").strip()

import repro.compat  # noqa: F401,E402  (jax API shims; must precede test imports)
import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    """A real 8-device ("d",) mesh on the host platform, in-process."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices "
                    f"(XLA_FLAGS {_DEVCOUNT_FLAG}=8; "
                    f"have {jax.device_count()})")
    return jax.make_mesh((8,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))

try:  # real hypothesis wins whenever it is available
    import hypothesis  # noqa: F401
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


if not _HAVE_HYPOTHESIS:

    class _Unsatisfied(Exception):
        """Raised by assume(False): discard the current draw."""

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _none():
        return _Strategy(lambda rng: None)

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: rng.choice(items))

    def _one_of(*strategies):
        return _Strategy(lambda rng: rng.choice(strategies).example(rng))

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))

    def _lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10
        return _Strategy(lambda rng: [
            elements.example(rng) for _ in range(rng.randint(min_size, hi))])

    def _permutations(seq):
        items = list(seq)

        def draw(rng):
            out = list(items)
            rng.shuffle(out)
            return out
        return _Strategy(draw)

    def _just(value):
        return _Strategy(lambda rng: value)

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.none = _none
    _st.sampled_from = _sampled_from
    _st.one_of = _one_of
    _st.tuples = _tuples
    _st.lists = _lists
    _st.permutations = _permutations
    _st.just = _just

    _DEFAULT_MAX_EXAMPLES = 20
    _EXAMPLE_CAP = 25          # keep bare-install sweeps fast

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._mh_settings = {"max_examples": max_examples}
            return fn
        return deco

    def _assume(condition):
        if not condition:
            raise _Unsatisfied()
        return True

    def _given(*garg_strategies, **gkw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # hypothesis maps positional strategies to the RIGHTMOST params
            pos_names = names[len(names) - len(garg_strategies):] \
                if garg_strategies else []
            filled = set(pos_names) | set(gkw_strategies)

            @functools.wraps(fn)
            def wrapper(**outer_kw):
                cfg = getattr(wrapper, "_mh_settings", None) or \
                    getattr(fn, "_mh_settings", {})
                n = min(cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES),
                        _EXAMPLE_CAP)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    kw = dict(outer_kw)
                    for name, strat in zip(pos_names, garg_strategies):
                        kw[name] = strat.example(rng)
                    for name, strat in gkw_strategies.items():
                        kw[name] = strat.example(rng)
                    try:
                        fn(**kw)
                    except _Unsatisfied:
                        continue
                    except Exception:
                        drawn = {k: v for k, v in kw.items() if k in filled}
                        print(f"\n[mini-hypothesis] falsifying example: "
                              f"{drawn}", file=sys.stderr)
                        raise

            # hide the strategy-filled params from pytest's fixture resolver
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in filled])
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = _assume
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, filter_too_much=None, data_too_large=None)
    _hyp.__version__ = "0.0-mini"

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
