from .synthetic import (road3d, skin, poker, spacenet_images, spacenet_pixels,
                        load, DATASETS, PAPER_SIZES, SPACENET_IMAGE_SHAPE)
