"""Synthetic stand-ins for the paper's four data sets (Table 1).

The container is offline, so we generate distribution-matched synthetics:

  · road3d     — 3D Road Network (434,874 × 4): points along noisy road
                 polylines over a 185×135 km region (lon/lat/alt + curvature).
  · skin       — Skin Segmentation (245,057 × 4): two BGR blob families
                 (skin tones vs. background) + luminance.
  · poker      — Poker Hand (1,025,010 × 11): 5× (suit, rank) + hand class
                 proxy; integer-valued, weakly clustered — the hard case.
  · spacenet   — SpaceNet imagery: [n_img, 438, 406, 3] spectral images with
                 k_true smooth regions (forest/water/road/… analogue).

Generators are deterministic in ``seed`` and accept ``n`` overrides so tests
run at reduced scale.  These are *workload* substitutes: the paper's claims
we validate are about convergence/cost behaviour, which depends on cluster
structure, not on the exact UCI bytes (DESIGN.md threats-to-validity note).
"""
from __future__ import annotations

import numpy as np

PAPER_SIZES = {"road3d": 434_874, "skin": 245_057, "poker": 1_025_010}
SPACENET_IMAGE_SHAPE = (438, 406, 3)


def road3d(n: int = 50_000, seed: int = 0) -> np.ndarray:
    """Points scattered along a handful of noisy polyline 'roads'."""
    rng = np.random.default_rng(seed)
    n_roads = 12
    pts = []
    per = n // n_roads
    for r in range(n_roads):
        t = rng.uniform(0, 1, size=(per,))
        start = rng.uniform([8.0, 56.5, 0.0], [10.5, 57.8, 60.0])
        end = rng.uniform([8.0, 56.5, 0.0], [10.5, 57.8, 60.0])
        base = start[None, :] + t[:, None] * (end - start)[None, :]
        wiggle = 0.02 * np.stack([np.sin(9 * t + r), np.cos(7 * t + r),
                                  5 * np.sin(3 * t)], axis=-1)
        xyz = base + wiggle + rng.normal(0, [0.004, 0.004, 1.5], size=(per, 3))
        curv = np.abs(np.gradient(xyz[:, 2])) + rng.normal(0, 0.1, per)
        pts.append(np.concatenate([xyz, curv[:, None]], axis=-1))
    out = np.concatenate(pts)[:n].astype(np.float32)
    return out[rng.permutation(out.shape[0])]


def skin(n: int = 50_000, seed: int = 0) -> np.ndarray:
    """Two BGR families: skin-tone manifold vs. broad background."""
    rng = np.random.default_rng(seed)
    n_skin = n // 2
    tone = rng.beta(2.0, 1.5, size=(n_skin, 1))
    skin_bgr = np.concatenate([
        120 + 60 * tone + rng.normal(0, 12, (n_skin, 1)),     # B
        140 + 70 * tone + rng.normal(0, 12, (n_skin, 1)),     # G
        180 + 70 * tone + rng.normal(0, 12, (n_skin, 1)),     # R
    ], axis=-1)
    n_bg = n - n_skin
    centers = rng.uniform(0, 255, size=(8, 3))
    which = rng.integers(0, 8, size=n_bg)
    bg = centers[which] + rng.normal(0, 25, (n_bg, 3))
    bgr = np.clip(np.concatenate([skin_bgr, bg]), 0, 255)
    lum = bgr @ np.array([0.114, 0.587, 0.299])
    out = np.concatenate([bgr, lum[:, None]], axis=-1).astype(np.float32)
    return out[rng.permutation(n)]


def poker(n: int = 50_000, seed: int = 0) -> np.ndarray:
    """5 cards × (suit 1–4, rank 1–13) + weak hand-type signal (11 attrs)."""
    rng = np.random.default_rng(seed)
    suits = rng.integers(1, 5, size=(n, 5)).astype(np.float32)
    ranks = rng.integers(1, 14, size=(n, 5)).astype(np.float32)
    # weak class-correlated structure: pairs share ranks
    has_pair = rng.random(n) < 0.42
    ranks[has_pair, 1] = ranks[has_pair, 0]
    cards = np.empty((n, 10), np.float32)
    cards[:, 0::2] = suits
    cards[:, 1::2] = ranks
    hand = has_pair.astype(np.float32) + (ranks.max(1) > 11)
    return np.concatenate([cards, hand[:, None]], axis=-1)


def spacenet_images(n_images: int = 4, k_true: int = 6, seed: int = 0,
                    shape: tuple[int, int, int] = SPACENET_IMAGE_SHAPE) -> np.ndarray:
    """[n_img, H, W, 3] images of k_true spatially-smooth spectral regions."""
    rng = np.random.default_rng(seed)
    h, w, c = shape
    # fixed spectral signatures (forest, water, road, building, grass, waste)
    sigs = np.array([[40, 90, 40], [20, 40, 90], [90, 90, 95],
                     [150, 130, 120], [90, 140, 60], [130, 110, 80]],
                    np.float32)[:k_true]
    imgs = np.empty((n_images, h, w, c), np.float32)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    for i in range(n_images):
        # smooth label field via low-frequency random mixtures
        field = np.zeros((h, w, k_true), np.float32)
        for k in range(k_true):
            for _ in range(3):
                fy, fx = rng.uniform(0.5, 3.0, 2)
                py, px = rng.uniform(0, 2 * np.pi, 2)
                field[:, :, k] += rng.uniform(0.4, 1.0) * np.sin(
                    2 * np.pi * fy * yy / h + py) * np.cos(2 * np.pi * fx * xx / w + px)
        labels = field.argmax(-1)
        img = sigs[labels] + rng.normal(0, 9.0, (h, w, c))
        imgs[i] = np.clip(img, 0, 255)
    return imgs


def spacenet_pixels(n_images: int = 4, k_true: int = 6, seed: int = 0,
                    shape=SPACENET_IMAGE_SHAPE) -> np.ndarray:
    """Flattened per-image pixel groups: [n_img, H·W, 3] (image = group, §5.2)."""
    imgs = spacenet_images(n_images, k_true, seed, shape)
    n, h, w, c = imgs.shape
    return imgs.reshape(n, h * w, c)


DATASETS = {"road3d": road3d, "skin": skin, "poker": poker}


def load(name: str, n: int | None = None, seed: int = 0) -> np.ndarray:
    if name in DATASETS:
        kwargs = {"seed": seed}
        if n is not None:
            kwargs["n"] = n
        return DATASETS[name](**kwargs)
    raise KeyError(f"unknown dataset {name!r}; choose from {sorted(DATASETS)} "
                   f"or use spacenet_pixels()")
