"""Zoo utilities: parameter counting, cache construction, input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a (architecture × shape) cell — weak-type-correct, shardable,
zero allocation — consumed by the multi-pod dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeConfig
from . import transformer, ssm


def count_params(cfg, active_only: bool = False) -> int:
    """Parameter count from the init structure via eval_shape (no alloc)."""
    shapes = jax.eval_shape(lambda k: transformer.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and cfg.moe is not None:
            names = "/".join(str(p) for p in path)
            if any(w in names for w in ("w_gate", "w_up", "w_down")) \
                    and "moe" in names and "shared" not in names:
                # only top_k of n_experts are active per token
                n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return int(total)


# --------------------------------------------------------------------------
# Cache structure (shape-level; serving allocates, dry-run uses specs)
# --------------------------------------------------------------------------

def cache_struct(cfg, batch: int, max_seq: int):
    """ShapeDtypeStruct pytree matching prefill's cache output."""
    p_n = cfg.n_periods
    kvh, dh = cfg.n_kv_heads, cfg.head_dim
    dt = cfg.act_dtype
    caches = {}
    for pos, kind in enumerate(cfg.period):
        if kind in ("attn", "attn_local", "attn_global"):
            seq = max_seq
            if (kind == "attn_local" and cfg.sliding_window
                    and cfg.windowed_local_cache):
                seq = min(max_seq, cfg.sliding_window)   # ring buffer
            caches[f"pos{pos}"] = {
                "k": jax.ShapeDtypeStruct((p_n, batch, seq, kvh, dh), dt),
                "v": jax.ShapeDtypeStruct((p_n, batch, seq, kvh, dh), dt),
            }
        elif kind == "mamba":
            d_in, _, n, d_conv = ssm.mamba_dims(cfg)
            caches[f"pos{pos}"] = {
                "h": jax.ShapeDtypeStruct((p_n, batch, d_in, n), jnp.float32),
                "conv": jax.ShapeDtypeStruct((p_n, batch, d_conv - 1, d_in),
                                             jnp.float32),
            }
        elif kind == "mlstm":
            d_in, h, dhh = ssm.mlstm_dims(cfg)
            caches[f"pos{pos}"] = {
                "s0": jax.ShapeDtypeStruct((p_n, batch, h, dhh, dhh), jnp.float32),
                "s1": jax.ShapeDtypeStruct((p_n, batch, h, dhh), jnp.float32),
                "s2": jax.ShapeDtypeStruct((p_n, batch, h), jnp.float32),
            }
        elif kind == "slstm":
            d = cfg.d_model
            caches[f"pos{pos}"] = {
                f"s{i}": jax.ShapeDtypeStruct((p_n, batch, d), jnp.float32)
                for i in range(4)}
        # cross: static image kv, recomputed per step — no cache entry
    return caches


def init_cache(cfg, batch: int, max_seq: int):
    """Zero-filled cache pytree (serving)."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_seq))


# --------------------------------------------------------------------------
# Input specs per (arch × shape) — dry-run stand-ins
# --------------------------------------------------------------------------

def input_specs(cfg, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.act_dtype
    if shape.kind == "train":
        if cfg.encoder_only:           # masked-prediction training (HuBERT)
            return {
                "embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                "targets": jax.ShapeDtypeStruct((b, s), i32),
                "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
            }
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.cross_attn_tokens, cfg.d_model), dt)
        return batch
    if shape.kind == "prefill":
        if cfg.embeddings_input and cfg.family == "audio":
            return {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.cross_attn_tokens, cfg.d_model), dt)
        return batch
    if shape.kind == "decode":
        batch = {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "caches": cache_struct(cfg, b, s),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.cross_attn_tokens, cfg.d_model), dt)
        return batch
    raise ValueError(shape.kind)
