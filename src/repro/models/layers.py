"""Core transformer layers — pure-functional JAX (init/apply pairs).

Covers every attention variant in the assigned pool: GQA with separate
head_dim (Qwen3/Nemo style), qk-norm (Qwen3/Gemma3), QKV bias (Qwen2),
causal / bidirectional (HuBERT) / sliding-window (Gemma3 local) / cross
(Llama-3.2-Vision), RoPE with per-kind theta, and KV-cache decode.

Attention math can route through the Pallas flash kernel
(``cfg.use_flash_kernel``) or the pure-jnp path (default — XLA-lowerable on
any backend; the dry-run uses this path so the compiled HLO is analysable).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distribution.hints import hint

Params = dict[str, Any]


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: Params, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * params["scale"]).astype(x.dtype)


def _head_rms(x, eps: float = 1e-6):
    """Per-head qk-norm (no learned scale folded per-layer for simplicity of
    the stacked-period parameterisation; Qwen3 uses a learned scale — we keep
    one, see init_attention)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x [..., S, H, dh], positions [..., S] (broadcastable) → rotated x."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                     # [dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(angles)[..., :, None, :]                  # [..., S, 1, dh/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def init_attention(key, cfg) -> Params:
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    s = 0.02
    p = {
        "wq": _normal(ks[0], (d, h * dh), s),
        "wk": _normal(ks[1], (d, kvh * dh), s),
        "wv": _normal(ks[2], (d, kvh * dh), s),
        "wo": _normal(ks[3], (h * dh, d), s / max(1, cfg.n_layers) ** 0.5),
        "norm": init_rmsnorm(d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((kvh * dh,), jnp.float32)
        p["bv"] = jnp.zeros((kvh * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dh,), jnp.float32)
        p["k_scale"] = jnp.ones((dh,), jnp.float32)
    return p


def _project_qkv(p: Params, x, cfg, theta: float, positions):
    b, s, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dtype = x.dtype
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kvh, dh)
    v = v.reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = _head_rms(q) * p["q_scale"].astype(dtype)
        k = _head_rms(k) * p["k_scale"].astype(dtype)
    if theta > 0:  # theta ≤ 0 disables RoPE (HuBERT uses none → conv pos stub)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


_CHUNK_THRESHOLD = 2048   # route long sequences through the O(S) jnp path


def _sdpa(q, k, v, *, causal: bool, window: int | None, q_positions=None,
          kv_positions=None, use_flash: bool = False):
    """q [B,S,H,dh], k/v [B,Skv,KVH,dh] → [B,S,H,dh].  GQA via reshape —
    grouped einsum, no K/V duplication (matches the flash kernel contract)."""
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    if use_flash:
        from repro.kernels.flash_attention import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal,
                            window=window)
        return o.transpose(0, 2, 1, 3)
    if (sq > _CHUNK_THRESHOLD and skv > _CHUNK_THRESHOLD
            and q_positions is None and kv_positions is None):
        return _sdpa_chunked(q, k, v, causal=causal, window=window)
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = (jnp.arange(sq) if q_positions is None else q_positions)
    kpos = (jnp.arange(skv) if kv_positions is None else kv_positions)
    if causal or window is not None:
        if qpos.ndim == 2:       # per-batch positions [B, sq] (serving slots)
            rows = qpos[:, :, None]
            cols = kpos[:, None, :] if kpos.ndim == 2 else kpos[None, None, :]
            mask = rows >= cols
            if window is not None:
                mask = jnp.logical_and(mask, cols > rows - window)
            scores = jnp.where(mask[:, None, None], scores, -1e30)
        else:
            rows, cols = qpos[:, None], kpos[None, :]
            mask = rows >= cols
            if window is not None:
                mask = jnp.logical_and(mask, cols > rows - window)
            scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)


def _sdpa_chunked(q, k, v, *, causal: bool, window: int | None,
                  block_q: int = 512, block_k: int = 1024):
    """Flash-style online-softmax attention in pure jnp — O(S·d) memory.

    The jnp analogue of kernels/flash_attention (same math, same masking):
    outer ``lax.map`` over q blocks, inner ``lax.scan`` over kv blocks
    carrying (m, l, acc).  This is what makes 32k-prefill / 4k-train lower
    without materialising the [S,S] score matrix.  Positions are implicit
    (0..S) — the cached-decode path never takes this route.
    """
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = dh ** -0.5
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k
    qg = (qp.reshape(b, nq, block_q, kvh, g, dh)
          .astype(jnp.float32) * scale)

    def q_block(qi):
        qb = qg[:, qi]                                       # [b,bq,kvh,g,dh]
        m0 = jnp.full((b, kvh, g, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, block_q, dh), jnp.float32)

        def kv_step(carry, kj):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kp, kj * block_k, block_k, 1)
            vb = jax.lax.dynamic_slice_in_dim(vp, kj * block_k, block_k, 1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb.astype(jnp.float32))
            rows = qi * block_q + jnp.arange(block_q)[:, None]
            cols = kj * block_k + jnp.arange(block_k)[None, :]
            mask = cols < skv                                 # kv padding
            if causal or window is not None:
                mask = jnp.logical_and(mask, rows >= cols)
            if window is not None:
                mask = jnp.logical_and(mask, cols > rows - window)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, -1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]        # [b,kvh,g,bq,dh]

    blocks = jax.lax.map(q_block, jnp.arange(nq))            # [nq,b,kvh,g,bq,dh]
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * block_q, h, dh)
    return out[:, :sq].astype(q.dtype)


def attention(p: Params, x, cfg, *, kind: str = "attn", positions=None,
              cache=None, cache_pos=None, cross_kv=None):
    """Pre-norm attention block.

    kind: attn | attn_local | attn_global | cross | attn_bidir
    cache: None (full forward) or dict(k=[B,Smax,KVH,dh], v=…) for decode;
    cache_pos: [] int32 — write offset for the new token(s);
    cross_kv: [B, T_img, D] image/frame embeddings for kind == "cross".

    Returns (out, new_cache).
    """
    b, s, d = x.shape
    theta = cfg.rope_theta
    window = None
    causal = not cfg.encoder_only
    if kind == "attn_local":
        window = cfg.sliding_window
    elif kind == "attn_global" and cfg.rope_theta_global is not None:
        theta = cfg.rope_theta_global
    elif kind == "cross":
        causal = False

    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    xn = hint(xn, "act_btd")

    if kind == "cross":
        # q from text stream; k/v from (static) image embeddings
        kv_src = rmsnorm(p["norm"], cross_kv, cfg.norm_eps) if cfg.cross_norm_kv else cross_kv
        q, _, _ = _project_qkv(p, xn, cfg, theta=-1.0,
                               positions=_default_pos(positions, s))
        kvh, dh = cfg.n_kv_heads, cfg.head_dim
        tk = kv_src.shape[1]
        k = (kv_src @ p["wk"].astype(x.dtype)).reshape(b, tk, kvh, dh)
        v = (kv_src @ p["wv"].astype(x.dtype)).reshape(b, tk, kvh, dh)
        o = _sdpa(q, k, v, causal=False, window=None,
                  use_flash=cfg.use_flash_kernel)
        gate = jnp.tanh(p["xgate"].astype(x.dtype)) if "xgate" in p else 1.0
        out = (o.reshape(b, s, -1) @ p["wo"].astype(x.dtype)) * gate
        return hint(out, "act_btd"), cache

    positions = _default_pos(positions, s)
    q, k, v = _project_qkv(p, xn, cfg, theta, positions)
    q = hint(q, "act_bshd")

    if cache is None:
        o = _sdpa(q, k, v, causal=causal, window=window,
                  use_flash=cfg.use_flash_kernel)
        new_cache = None
    else:
        pos_arr = jnp.asarray(cache_pos)
        skv = cache["k"].shape[1]
        ring = window is not None and skv <= window   # windowed ring buffer
        write_pos = pos_arr % skv if ring else pos_arr
        if pos_arr.ndim == 0:     # shared position → dynamic-update-slice
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), write_pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), write_pos, axis=1)
        else:                     # per-slot positions [B] → scatter
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, write_pos].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, write_pos].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        if ring:
            # slot j holds absolute position p = pos − ((pos − j) mod W);
            # never-written slots (p < 0) get a sentinel that fails causality
            slots = jnp.arange(skv)
            if pos_arr.ndim == 0:
                kvp = pos_arr - ((pos_arr - slots) % skv)          # [W]
            else:
                kvp = pos_arr[:, None] - ((pos_arr[:, None] - slots[None]) % skv)
            kvp = jnp.where(kvp < 0, 1 << 30, kvp)
        else:
            kvp = jnp.arange(skv)
        o = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=True,
                  window=window, q_positions=positions,
                  kv_positions=kvp, use_flash=False)
    out = o.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    return hint(out, "act_btd"), new_cache


def _default_pos(positions, s):
    return jnp.arange(s) if positions is None else positions


def init_cross_attention(key, cfg) -> Params:
    p = init_attention(key, cfg)
    p["xgate"] = jnp.zeros((), jnp.float32)   # tanh-gated, starts closed
    return p


# --------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# --------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, n_layers: int = 1) -> Params:
    ks = jax.random.split(key, 3)
    s = 0.02
    return {
        "w_gate": _normal(ks[0], (d, f), s),
        "w_up": _normal(ks[1], (d, f), s),
        "w_down": _normal(ks[2], (f, d), s / max(1, n_layers) ** 0.5),
        "norm": init_rmsnorm(d),
    }


def mlp(p: Params, x, eps: float = 1e-6):
    xn = rmsnorm(p["norm"], x, eps)
    dtype = x.dtype
    g = jax.nn.silu(xn @ p["w_gate"].astype(dtype))
    u = xn @ p["w_up"].astype(dtype)
    h = hint(g * u, "act_btf")
    return h @ p["w_down"].astype(dtype)
