"""Mixture-of-Experts layer — static-capacity, sort-based dispatch.

Dispatch avoids the dense [T, E, C] one-hot einsum (at 128 experts it costs
more FLOPs than the experts themselves): tokens' (slot → expert) assignments
are sorted by expert, ranks within each expert computed from cumulative
counts, and tokens scattered into an [E, C, D] buffer.  Tokens over capacity
are dropped (contribute zero — standard Switch behaviour); capacity factor
is configurable per arch.

Expert parallelism: the [E, C, D] buffer and [E, …] weights carry "expert"
sharding hints, so under the production mesh experts live sharded over the
"model" axis and XLA inserts the token all-to-alls.  Router is replicated.

Variants covered: top-1 + shared expert (Llama-4-Scout), top-8 of 128
(Qwen3-MoE), top-2 of 16 on alternating layers (Jamba).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.hints import hint
from .layers import Params, init_rmsnorm, rmsnorm, init_mlp, mlp, _normal


def init_moe(key, cfg) -> Params:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    s = 0.02
    p = {
        "router": _normal(ks[0], (d, e), s),
        "w_gate": _normal(ks[1], (e, d, f), s),
        "w_up": _normal(ks[2], (e, d, f), s),
        "w_down": _normal(ks[3], (e, f, d), s / max(1, cfg.n_layers) ** 0.5),
        "norm": init_rmsnorm(d),
    }
    if m.shared_expert:
        p["shared"] = init_mlp(ks[4], d, f, cfg.n_layers)
    return p


def _capacity(t: int, m) -> int:
    c = int(t * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 (sublane alignment)


def _dispatch_combine(xn, top_p, top_e, expert_fn, e: int, k: int, cap: int,
                      dtype):
    """Sort-based scatter → expert_fn([E,C,D]) → weighted gather, for one
    dispatch group.  ``expert_fn`` runs the expert einsums."""
    t = xn.shape[0]
    d = xn.shape[-1]
    flat_e = top_e.reshape(-1)                               # [T*k]
    order = jnp.argsort(flat_e)                              # stable
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                     # [E]
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap                                        # over-capacity drop

    src_tok = jnp.arange(t * k, dtype=jnp.int32) // k        # token of each slot
    buf_idx = jnp.where(keep, flat_e * cap + rank, e * cap)  # sentinel row
    buffer = jnp.zeros((e * cap + 1, d), dtype).at[buf_idx].set(xn[src_tok])
    out_buf = expert_fn(buffer[:-1].reshape(e, cap, d))      # [E, C, D]

    flat_out = jnp.concatenate(
        [out_buf.reshape(e * cap, d), jnp.zeros((1, d), dtype)])
    slot_out = flat_out[buf_idx]                             # [T*k,D] (0 if drop)
    weighted = slot_out * top_p.reshape(-1)[:, None].astype(dtype)
    return jnp.sum(weighted.reshape(t, k, d), axis=1)


def moe(p: Params, x, cfg):
    """x [B, S, D] → [B, S, D].  Returns (out, aux) with load-balance loss.

    ``cfg.moe_dispatch_groups = G > 1`` (§Perf hillclimb #2) splits tokens
    into G groups dispatched independently (vmap): with G aligned to the DP
    shard count, scatter/gather stay shard-local and the only cross-shard
    traffic is the [G,E,C,D] buffer all-to-all into expert-parallel layout.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    cap = _capacity(t, m)
    dtype = x.dtype

    xn = rmsnorm(p["norm"], x, cfg.norm_eps).reshape(t, d)

    # --- routing (f32 for numerics) ---
    logits = xn.astype(jnp.float32) @ p["router"]            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # [T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch): E · Σ_e f_e · P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    groups = cfg.moe_dispatch_groups
    if groups > 1 and t % groups == 0:
        tg = t // groups
        cap_g = max(8, -(-cap // groups) // 8 * 8 + 8)

        def expert_fn_grouped(buffers):                      # [G, E, Cg, D]
            buffers = hint(buffers, "moe_gecd_ep")           # a2a: G→E layout
            g_ = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buffers,
                                        p["w_gate"].astype(dtype)))
            u_ = jnp.einsum("gecd,edf->gecf", buffers, p["w_up"].astype(dtype))
            h_ = hint(g_ * u_, "moe_gecf_ep")
            ob = jnp.einsum("gecf,efd->gecd", h_, p["w_down"].astype(dtype))
            return hint(ob, "moe_gecd_dp")                   # a2a back: E→G

        xg = hint(xn.reshape(groups, tg, d), "moe_gtd")
        pg = top_p.reshape(groups, tg, k)
        eg = top_e.reshape(groups, tg, k)
        # vmapped local dispatch; expert compute batched over groups afterwards
        buffers = jax.vmap(
            lambda xx, pp, ee: _scatter_only(xx, pp, ee, e, k, cap_g, dtype)
        )(xg, pg, eg)
        out_buf = expert_fn_grouped(buffers[0])
        out = jax.vmap(
            lambda ob, idx, pp: _gather_only(ob, idx, pp, e, cap_g, dtype)
        )(out_buf, buffers[1], pg).reshape(t, d)
    else:
        def expert_fn(buffer):                               # [E, C, D]
            buffer = hint(buffer, "moe_ecd")
            g_ = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buffer,
                                        p["w_gate"].astype(dtype)))
            u_ = jnp.einsum("ecd,edf->ecf", buffer, p["w_up"].astype(dtype))
            h_ = hint(g_ * u_, "moe_ecf")
            ob = jnp.einsum("ecf,efd->ecd", h_, p["w_down"].astype(dtype))
            return hint(ob, "moe_ecd")

        out = _dispatch_combine(xn, top_p, top_e, expert_fn, e, k, cap, dtype)

    if m.shared_expert:
        out = out + mlp(p["shared"], x, cfg.norm_eps).reshape(t, d)

    return out.reshape(b, s, d), aux


def _scatter_only(xn, top_p, top_e, e, k, cap, dtype):
    """Per-group scatter → ([E,C,D] buffer, buf_idx) for the grouped path."""
    del top_p  # combine weight applies at the gather leg, not here
    t, d = xn.shape
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    src_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    buf_idx = jnp.where(keep, flat_e * cap + rank, e * cap)
    buffer = jnp.zeros((e * cap + 1, d), dtype).at[buf_idx].set(xn[src_tok])
    return buffer[:-1].reshape(e, cap, d), buf_idx


def _gather_only(out_buf, buf_idx, top_p, e, cap, dtype):
    t, k = top_p.shape
    d = out_buf.shape[-1]
    flat_out = jnp.concatenate(
        [out_buf.reshape(e * cap, d), jnp.zeros((1, d), dtype)])
    slot_out = flat_out[buf_idx]
    weighted = slot_out * top_p.reshape(-1)[:, None].astype(dtype)
    return jnp.sum(weighted.reshape(t, k, d), axis=1)
