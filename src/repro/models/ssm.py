"""State-space / recurrent blocks: Mamba S6 (Jamba) and xLSTM (mLSTM, sLSTM).

All three are linear-state recurrences: O(1) state per layer, which is what
makes the `long_500k` decode shape tractable for these families.  Training
uses ``lax.scan`` over time with per-step gate computation (the [B,S,d_in,N]
discretised-A tensor is never materialised — DESIGN.md hardware-adaptation
note); decode reuses the same cell functions one step at a time.

Simplifications vs. the reference CUDA implementations (documented):
  · Mamba: recurrent scan instead of the chunked parallel scan kernel — the
    HLO stays one While op (compile-friendly); a Pallas chunked scan is the
    listed TPU follow-up.
  · mLSTM: no short conv on the qkv branch; plain per-head projections.
  · sLSTM: block-diagonal (per-head) recurrent matrices, as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.hints import hint
from .layers import Params, init_rmsnorm, rmsnorm, _normal


# --------------------------------------------------------------------------
# Mamba (S6)
# --------------------------------------------------------------------------

def mamba_dims(cfg):
    d = cfg.d_model
    mc = cfg.mamba
    d_in = mc.expand * d
    dt_rank = max(1, -(-d // 16))
    return d_in, dt_rank, mc.d_state, mc.d_conv


def init_mamba(key, cfg) -> Params:
    d = cfg.d_model
    d_in, dt_rank, n, d_conv = mamba_dims(cfg)
    ks = jax.random.split(key, 7)
    s = 0.02
    # S4D-real initialisation for A
    a_init = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))
    return {
        "norm": init_rmsnorm(d),
        "in_proj": _normal(ks[0], (d, 2 * d_in), s),
        "conv_w": _normal(ks[1], (d_conv, d_in), 0.2),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": _normal(ks[2], (d_in, dt_rank + 2 * n), s),
        "dt_w": _normal(ks[3], (dt_rank, d_in), s),
        "dt_b": jnp.log(jnp.expm1(jnp.full((d_in,), 1e-2))),  # softplus⁻¹(0.01)
        "A_log": jnp.log(a_init),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _normal(ks[4], (d_in, d), s / max(1, cfg.n_layers) ** 0.5),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along S: x [B,S,C], w [K,C] → [B,S,C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):   # K is 4 — unrolled adds, no conv primitive needed
        out = out + pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _mamba_cell(h, a_neg, dt, bmat, cmat, xt):
    """One S6 step. h [B,d_in,N]; dt/xt [B,d_in]; bmat/cmat [B,N]."""
    da = jnp.exp(dt[..., None] * a_neg[None])                 # [B,d_in,N]
    dbx = (dt * xt)[..., None] * bmat[:, None, :]             # [B,d_in,N]
    h = da * h + dbx
    y = jnp.sum(h * cmat[:, None, :], axis=-1)                # [B,d_in]
    return h, y


def mamba(p: Params, x, cfg, state=None, conv_state=None):
    """x [B,S,D] → ([B,S,D], (state, conv_state)).

    state: [B,d_in,N] recurrent state (decode); conv_state: [B,K-1,d_in].
    When state is None (training/prefill) both start at zero and the final
    states are returned for cache hand-off.
    """
    b, s, d = x.shape
    d_in, dt_rank, n, d_conv = mamba_dims(cfg)
    dtype = x.dtype
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)

    xz = xn @ p["in_proj"].astype(dtype)
    x1, z = jnp.split(xz, 2, axis=-1)                        # [B,S,d_in] ×2

    if conv_state is not None:   # decode: prepend cached inputs
        full = jnp.concatenate([conv_state.astype(dtype), x1], axis=1)
        conv_out = _causal_conv(full, p["conv_w"].astype(dtype),
                                p["conv_b"].astype(dtype))[:, -s:]
        new_conv = full[:, -(d_conv - 1):]
    else:
        conv_out = _causal_conv(x1, p["conv_w"].astype(dtype),
                                p["conv_b"].astype(dtype))
        new_conv = x1[:, -(d_conv - 1):]
    x1 = jax.nn.silu(conv_out)

    dbc = x1 @ p["x_proj"].astype(dtype)
    dt_raw, bmat, cmat = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_w"].astype(dtype)
                         + p["dt_b"].astype(dtype))          # [B,S,d_in]
    a_neg = -jnp.exp(p["A_log"])                             # [d_in,N] f32

    h0 = (jnp.zeros((b, d_in, n), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        h, y = _mamba_cell(h, a_neg, dt_t.astype(jnp.float32),
                           b_t.astype(jnp.float32), c_t.astype(jnp.float32),
                           x_t.astype(jnp.float32))
        return h, y

    xs = (dt.swapaxes(0, 1), bmat.swapaxes(0, 1), cmat.swapaxes(0, 1),
          x1.swapaxes(0, 1))                                 # time-major
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1).astype(dtype)                      # [B,S,d_in]
    y = y + p["D"].astype(dtype) * x1
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(dtype)
    return hint(out, "act_btd"), (h_final, new_conv)


# --------------------------------------------------------------------------
# xLSTM — mLSTM (matrix memory)
# --------------------------------------------------------------------------

def mlstm_dims(cfg):
    d = cfg.d_model
    d_in = int(cfg.xlstm_mlstm_proj * d)
    h = cfg.n_heads
    return d_in, h, d_in // h


def init_mlstm(key, cfg) -> Params:
    d = cfg.d_model
    d_in, h, dh = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    s = 0.02
    return {
        "norm": init_rmsnorm(d),
        "up": _normal(ks[0], (d, 2 * d_in), s),
        "wq": _normal(ks[1], (d_in, d_in), s),
        "wk": _normal(ks[2], (d_in, d_in), s),
        "wv": _normal(ks[3], (d_in, d_in), s),
        "wi": _normal(ks[4], (d_in, h), s),
        "bi": jnp.zeros((h,), jnp.float32),
        "wf": _normal(ks[5], (d_in, h), s),
        "bf": jnp.full((h,), 3.0),     # forget-gate bias: remember by default
        "gnorm": jnp.ones((d_in,), jnp.float32),
        "down": _normal(ks[6], (d_in, d), s / max(1, cfg.n_layers) ** 0.5),
    }


def _mlstm_cell(carry, q, k, v, i_raw, f_raw):
    """Stabilised mLSTM step. carry = (C [B,H,dh,dh], n [B,H,dh], m [B,H])."""
    C, n, m = carry
    f_log = jax.nn.log_sigmoid(f_raw)                        # [B,H]
    m_new = jnp.maximum(f_log + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)[..., None]                  # [B,H,1]
    f_p = jnp.exp(f_log + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_p * n + i_p * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)                  # C qᵀ
    den = jnp.maximum(jnp.abs(jnp.sum(n * q, -1)), 1.0)[..., None]
    return (C, n, m_new), num / den                          # h_t [B,H,dh]


def _mlstm_chunked(state, q, k, v, i_raw, f_raw, chunk: int):
    """Chunkwise-parallel mLSTM — exact reimplementation of the sequential
    stabilised cell (same m_t sequence, same clamp), with intra-chunk work as
    [L,L]×[L,dh] matmuls and states touched once per chunk.

    Unrolling the cell gives, with b_t = Σ_{s≤t} logσ(f_s) (within-chunk
    cumulative) and m_t = max(m₀ + b_t, max_{s≤t}(b_t − b_s + i_s)):

        C_t = e^{m₀+b_t−m_t}·C₀ + Σ_{s≤t} e^{b_t−b_s+i_s−m_t} v_s k_sᵀ
        n_t = e^{m₀+b_t−m_t}·n₀ + Σ_{s≤t} e^{b_t−b_s+i_s−m_t} k_s
        h_t = (q_t·C_t) / max(|n_t·q_t|, 1)

    q/k/v [B,S,H,dh] f32, i/f [B,S,H] f32.  Returns (state, h [B,S,H·dh]).
    """
    b, s, h, dh = q.shape
    n_chunks = s // chunk
    qc = q.reshape(b, n_chunks, chunk, h, dh).swapaxes(0, 1)  # [N,B,L,H,dh]
    kc = k.reshape(b, n_chunks, chunk, h, dh).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, h, dh).swapaxes(0, 1)
    ic = i_raw.reshape(b, n_chunks, chunk, h).swapaxes(0, 1)  # [N,B,L,H]
    fc = f_raw.reshape(b, n_chunks, chunk, h).swapaxes(0, 1)

    def one_chunk(carry, xs):
        C0, n0, m0 = carry                       # [B,H,dh,dh],[B,H,dh],[B,H]
        qb, kb, vb, ib, fb = xs                  # [B,L,H,dh] / [B,L,H]
        blog = jnp.cumsum(jax.nn.log_sigmoid(fb), axis=1)     # [B,L,H] b_t
        # m_t = max(m₀ + b_t, running-max_{s≤t}(b_t − b_s + i_s))
        g = ib - blog                            # [B,L,H]  (i_s − b_s)
        gmax = jax.lax.cummax(g, axis=1)
        m = jnp.maximum(m0[:, None] + blog, blog + gmax)      # [B,L,H]
        # decay matrix D[t,s] = exp(b_t − b_s + i_s − m_t), s ≤ t
        expo = (blog[:, :, None] - blog[:, None, :] + ib[:, None, :]
                - m[:, :, None])                 # [B,L,L,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri[None, :, :, None], jnp.exp(expo), 0.0)
        scores = jnp.einsum("blhd,bshd->blsh", qb, kb)        # [B,L,L,H]
        w = scores * D
        intra_num = jnp.einsum("blsh,bshd->blhd", w, vb)      # [B,L,H,dh]
        intra_den = jnp.sum(w, axis=2)                        # [B,L,H]
        alpha = jnp.exp(m0[:, None] + blog - m)               # [B,L,H]
        # reference contracts q with C's SECOND index (C[d,e] ~ v_d k_e)
        inter_num = alpha[..., None] * jnp.einsum("blhe,bhde->blhd", qb, C0)
        inter_den = alpha * jnp.einsum("blhd,bhd->blh", qb, n0)
        den = jnp.maximum(jnp.abs(inter_den + intra_den), 1.0)[..., None]
        hs = (inter_num + intra_num) / den                    # [B,L,H,dh]
        # carry to next chunk (t = L)
        mL = m[:, -1]                                         # [B,H]
        bL = blog[:, -1]                                      # [B,H]
        wL = jnp.exp(bL[:, None] - blog + ib - mL[:, None])   # [B,L,H]
        beta = jnp.exp(m0 + bL - mL)                          # [B,H]
        C_new = (beta[..., None, None] * C0
                 + jnp.einsum("blh,blhd,blhe->bhde", wL, vb, kb))
        n_new = beta[..., None] * n0 + jnp.einsum("blh,blhd->bhd", wL, kb)
        return (C_new, n_new, mL), hs

    carry, hs = jax.lax.scan(one_chunk, state, (qc, kc, vc, ic, fc))
    # hs [N,B,L,H,dh] → [B,S,H·dh]
    out = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h * dh)
    return carry, out


def mlstm(p: Params, x, cfg, state=None):
    """x [B,S,D] → ([B,S,D], state). state = (C, n, m).

    ``cfg.xlstm_chunk > 0`` routes through the chunkwise-parallel form
    (identical math, §Perf hillclimb #1): per-token state IO becomes
    per-chunk [L,L]/[L,dh] matmuls — the roofline memory term drops ≈ L×.
    """
    b, s, d = x.shape
    d_in, h, dh = mlstm_dims(cfg)
    dtype = x.dtype
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    a, z = jnp.split(xn @ p["up"].astype(dtype), 2, axis=-1)  # [B,S,d_in]

    def heads(w):
        return (a @ w.astype(dtype)).reshape(b, s, h, dh).astype(jnp.float32)
    q, k, v = heads(p["wq"]), heads(p["wk"]) * dh ** -0.5, heads(p["wv"])
    i_raw = (a @ p["wi"].astype(dtype) + p["bi"].astype(dtype)).astype(jnp.float32)
    f_raw = (a @ p["wf"].astype(dtype) + p["bf"].astype(dtype)).astype(jnp.float32)

    if state is None:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))

    chunk = getattr(cfg, "xlstm_chunk", 0)
    if chunk and s > 1 and s % chunk == 0:
        state, hs_bsd = _mlstm_chunked(state, q, k, v, i_raw, f_raw, chunk)
        ht = hs_bsd.reshape(b, s, d_in)
    else:
        def step(carry, inp):
            qt, kt, vt, it, ft = inp
            return _mlstm_cell(carry, qt, kt, vt, it, ft)

        xs = tuple(t.swapaxes(0, 1) for t in (q, k, v, i_raw, f_raw))
        state, hs = jax.lax.scan(step, state, xs)
        ht = hs.swapaxes(0, 1).reshape(b, s, d_in)            # [B,S,d_in]
    # per-head group norm
    hg = ht.reshape(b, s, h, dh)
    mu = jnp.mean(hg, -1, keepdims=True)
    var = jnp.var(hg, -1, keepdims=True)
    ht = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(b, s, d_in)
    ht = (ht * p["gnorm"]).astype(dtype)
    out = (ht * jax.nn.silu(z)) @ p["down"].astype(dtype)
    return hint(out, "act_btd"), state


# --------------------------------------------------------------------------
# xLSTM — sLSTM (scalar memory, per-head recurrent mixing)
# --------------------------------------------------------------------------

def init_slstm(key, cfg) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    s = 0.02
    f_ff = int(cfg.xlstm_slstm_proj * d)
    return {
        "norm": init_rmsnorm(d),
        "w": _normal(ks[0], (d, 4 * d), s),                  # i,f,z,o from x
        "r": _normal(ks[1], (h, dh, 4 * dh), s),             # block-diag recurrent
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "gnorm": jnp.ones((d,), jnp.float32),
        # post-block GLU FFN, proj factor 4/3
        "ff_gate": _normal(ks[2], (d, f_ff), s),
        "ff_up": _normal(ks[2], (d, f_ff), s),
        "ff_down": _normal(ks[3], (f_ff, d), s / max(1, cfg.n_layers) ** 0.5),
    }


def _slstm_cell(carry, wx_t, r):
    """carry = (c, n, m, h) each [B,d]; wx_t [B,4d] precomputed Wx."""
    c, n, m, hprev = carry
    b, d = c.shape
    nh, dh, _ = r.shape
    hp = hprev.reshape(b, nh, dh)
    rec = jnp.einsum("bhe,hef->bhf", hp, r).reshape(b, 4 * d)
    gates = wx_t + rec
    i_raw, f_raw, z_raw, o_raw = jnp.split(gates, 4, axis=-1)
    f_log = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(f_log + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(f_log + m - m_new)
    c = f_p * c + i_p * jnp.tanh(z_raw)
    n = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h_new), h_new


def slstm(p: Params, x, cfg, state=None):
    """x [B,S,D] → ([B,S,D], state). state = (c, n, m, h)."""
    b, s, d = x.shape
    h_heads = cfg.n_heads
    dtype = x.dtype
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    wx = (xn @ p["w"].astype(dtype) + p["b"].astype(dtype)).astype(jnp.float32)

    if state is None:
        zero = jnp.zeros((b, d), jnp.float32)
        state = (zero, zero, jnp.full((b, d), -1e30, jnp.float32), zero)

    r = p["r"].astype(jnp.float32)

    def step(carry, wx_t):
        return _slstm_cell(carry, wx_t, r)

    state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    ht = hs.swapaxes(0, 1)                                   # [B,S,d]
    hg = ht.reshape(b, s, h_heads, d // h_heads)
    mu = jnp.mean(hg, -1, keepdims=True)
    var = jnp.var(hg, -1, keepdims=True)
    ht = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(b, s, d)
    out = (ht * p["gnorm"]).astype(dtype)
    # GLU FFN (proj factor 4/3)
    g = jax.nn.silu(out @ p["ff_gate"].astype(dtype))
    u = out @ p["ff_up"].astype(dtype)
    return (g * u) @ p["ff_down"].astype(dtype), state
