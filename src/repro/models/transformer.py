"""Model assembly: period-scanned heterogeneous stacks.

A model is ``n_periods`` repetitions of a *period* (tuple of layer kinds).
Parameters for each period position are stacked over periods ([P, ...]
leaves) and the stack runs under ``jax.lax.scan`` — HLO stays one While op
regardless of depth (48-layer models compile like 1-period models), remat
applies per period, and decode threads per-period cache slices through the
scan.

Entry points:
  init_lm(key, cfg)                         → params
  forward(params, cfg, tokens/embeddings)   → (logits, aux)           train fwd
  lm_loss(params, cfg, batch)               → (loss, metrics)
  prefill(params, cfg, batch)               → (logits, caches)        serving
  decode_step(params, cfg, token, caches, pos) → (logits, new caches)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distribution.hints import hint
from . import layers, moe as moe_mod, ssm
from .layers import Params


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _init_block(key, cfg, period_pos: int) -> Params:
    kind = cfg.period[period_pos]
    k1, k2 = jax.random.split(key)
    p: Params = {}
    if kind in ("attn", "attn_local", "attn_global"):
        p["attn"] = layers.init_attention(k1, cfg)
    elif kind == "cross":
        p["attn"] = layers.init_cross_attention(k1, cfg)
    elif kind == "mamba":
        p["mamba"] = ssm.init_mamba(k1, cfg)
    elif kind == "mlstm":
        p["cell"] = ssm.init_mlstm(k1, cfg)
    elif kind == "slstm":
        p["cell"] = ssm.init_slstm(k1, cfg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.has_ffn_at(period_pos):
        if cfg.moe_at(period_pos):
            p["moe"] = moe_mod.init_moe(k2, cfg)
        else:
            p["mlp"] = layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.n_layers)
    return p


def init_lm(key, cfg) -> Params:
    keys = jax.random.split(key, len(cfg.period) + 3)
    params: Params = {"blocks": {}}
    for pos in range(len(cfg.period)):
        pkeys = jax.random.split(keys[pos], cfg.n_periods)
        params["blocks"][f"pos{pos}"] = jax.vmap(
            lambda k, _pos=pos: _init_block(k, cfg, _pos))(pkeys)
    # audio-family stubs take frame embeddings directly — no token table;
    # VLMs keep the text embedding table (images enter via cross-attention).
    if not (cfg.embeddings_input and cfg.family == "audio"):
        params["embed"] = (jax.random.normal(keys[-3], (cfg.vocab, cfg.d_model))
                           * 0.02).astype(jnp.float32)
    params["final_norm"] = layers.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab))
                          * 0.02).astype(jnp.float32)
    return params


# --------------------------------------------------------------------------
# Period application (shared by train fwd / prefill / decode)
# --------------------------------------------------------------------------

def _apply_block(bp: Params, h, cfg, pos: int, *, mode: str, cache=None,
                 cache_pos=None, image_embeds=None, positions=None):
    """One layer (sublayer + optional FFN). Returns (h, new_cache, aux)."""
    kind = cfg.period[pos]
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if kind in ("attn", "attn_local", "attn_global"):
        if mode == "forward":
            att, _ = layers.attention(bp["attn"], h, cfg, kind=kind,
                                      positions=positions)
        elif mode == "prefill":
            att, new_cache = _attn_prefill(bp["attn"], h, cfg, kind, positions)
        else:  # decode
            att, new_cache = layers.attention(bp["attn"], h, cfg, kind=kind,
                                              positions=positions, cache=cache,
                                              cache_pos=cache_pos)
        h = h + att
    elif kind == "cross":
        att, _ = layers.attention(bp["attn"], h, cfg, kind="cross",
                                  positions=positions, cross_kv=image_embeds)
        h = h + att
    elif kind == "mamba":
        state = None if cache is None else cache["h"]
        conv = None if cache is None else cache["conv"]
        out, (hs, cs) = ssm.mamba(bp["mamba"], h, cfg, state=state,
                                  conv_state=conv)
        h = h + out
        if mode != "forward":
            new_cache = {"h": hs, "conv": cs.astype(jnp.float32)}
    elif kind in ("mlstm", "slstm"):
        fn = ssm.mlstm if kind == "mlstm" else ssm.slstm
        state = None if cache is None else tuple(cache[f"s{i}"]
                                                 for i in range(_n_states(kind)))
        out, new_state = fn(bp["cell"], h, cfg, state=state)
        h = h + out
        if mode != "forward":
            new_cache = {f"s{i}": s for i, s in enumerate(new_state)}
    if cfg.has_ffn_at(pos):
        if cfg.moe_at(pos):
            out, aux = moe_mod.moe(bp["moe"], h, cfg)
        else:
            out = layers.mlp(bp["mlp"], h, cfg.norm_eps)
        h = h + out
    return h, new_cache, aux


def _n_states(kind: str) -> int:
    return 3 if kind == "mlstm" else 4


def _attn_prefill(p, h, cfg, kind, positions):
    """Full attention forward that also returns the (k, v) cache."""
    b, s, d = h.shape
    theta = cfg.rope_theta
    window = None
    if kind == "attn_local":
        window = cfg.sliding_window
    elif kind == "attn_global" and cfg.rope_theta_global is not None:
        theta = cfg.rope_theta_global
    xn = layers.rmsnorm(p["norm"], h, cfg.norm_eps)
    pos = jnp.arange(s) if positions is None else positions
    q, k, v = layers._project_qkv(p, xn, cfg, theta, pos)
    o = layers._sdpa(q, k, v, causal=not cfg.encoder_only, window=window,
                     use_flash=cfg.use_flash_kernel)
    out = o.reshape(b, s, -1) @ p["wo"].astype(h.dtype)
    if (kind == "attn_local" and window is not None
            and cfg.windowed_local_cache and s >= window):
        # emit the ring buffer: the last `window` positions at slots p % W
        idx = jnp.arange(s - window, s) % window
        ck = jnp.zeros((b, window) + k.shape[2:], k.dtype).at[:, idx].set(
            k[:, s - window:])
        cv = jnp.zeros((b, window) + v.shape[2:], v.dtype).at[:, idx].set(
            v[:, s - window:])
        return hint(out, "act_btd"), {"k": ck, "v": cv}
    return hint(out, "act_btd"), {"k": k, "v": v}


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------

def _embed(params, cfg, tokens=None, embeddings=None):
    if embeddings is not None:
        return embeddings.astype(cfg.act_dtype)
    h = params["embed"][tokens]
    return hint(h.astype(cfg.act_dtype), "act_btd")


def _unembed(params, cfg, h):
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = h @ w.astype(h.dtype)
    return hint(logits, "logits_btv")


def _scan_periods(params, cfg, h, *, mode: str, caches=None, cache_pos=None,
                  image_embeds=None, positions=None):
    """Run the stacked periods. Returns (h, new_caches, aux_total)."""
    n_pos = len(cfg.period)

    remat_blocks = cfg.remat == "period" and mode == "forward"

    def period_fn(h, xs):
        blocks, caches_p = xs
        aux_total = jnp.zeros((), jnp.float32)
        new_caches_p = {}
        for pos in range(n_pos):
            cache = None if caches_p is None else caches_p.get(f"pos{pos}")
            block_fn = functools.partial(
                _apply_block, cfg=cfg, pos=pos, mode=mode,
                cache_pos=cache_pos, image_embeds=image_embeds,
                positions=positions)
            if remat_blocks and n_pos > 1:
                # nested remat: outer checkpoint saves only period carries;
                # inner checkpoints bound the recompute's live set to one
                # block (multi-layer periods: jamba/gemma/xlstm/vision)
                block_fn = jax.checkpoint(block_fn)
            h, nc, aux = block_fn(blocks[f"pos{pos}"], h, cache=cache)
            aux_total = aux_total + aux
            if nc is not None:
                new_caches_p[f"pos{pos}"] = nc
        return h, (new_caches_p or None, aux_total)

    fn = period_fn
    if remat_blocks:
        fn = jax.checkpoint(period_fn,
                            policy=jax.checkpoint_policies.nothing_saveable)

    xs = (params["blocks"], caches)
    h, (new_caches, auxs) = jax.lax.scan(fn, h, xs)
    return h, new_caches, jnp.sum(auxs)


def forward(params, cfg, tokens=None, embeddings=None, image_embeds=None):
    """Training/eval forward pass → (logits [B,S,V], moe_aux)."""
    h = _embed(params, cfg, tokens, embeddings)
    if image_embeds is not None:
        image_embeds = image_embeds.astype(cfg.act_dtype)
    h, _, aux = _scan_periods(params, cfg, h, mode="forward",
                              image_embeds=image_embeds)
    return _unembed(params, cfg, h), aux


def lm_loss(params, cfg, batch, aux_weight: float = 0.01):
    """Causal-LM or masked-prediction loss → (loss, metrics dict).

    batch: {"tokens": [B,S]} (+ "embeddings", "image_embeds", "mask",
    "targets" as the family requires).
    """
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeddings=batch.get("embeddings"),
                          image_embeds=batch.get("image_embeds"))
    logits = logits.astype(jnp.float32)
    if cfg.encoder_only:
        targets = batch["targets"]
        mask = batch["mask"].astype(jnp.float32)
    else:
        targets = batch["tokens"][:, 1:]
        logits = logits[:, :-1]
        mask = jnp.ones(targets.shape, jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    total = loss + aux_weight * aux
    return total, {"loss": loss, "moe_aux": aux,
                   "perplexity_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------

def prefill(params, cfg, tokens=None, embeddings=None, image_embeds=None):
    """Forward pass that materialises every layer's cache → (logits, caches)."""
    h = _embed(params, cfg, tokens, embeddings)
    if image_embeds is not None:
        image_embeds = image_embeds.astype(cfg.act_dtype)
    if cfg.encoder_only:
        h, _, _ = _scan_periods(params, cfg, h, mode="forward",
                                image_embeds=image_embeds)
        return _unembed(params, cfg, h), None
    # caches=None in prefill mode → blocks create their caches
    n_pos = len(cfg.period)

    def period_fn(h, blocks):
        new_caches_p = {}
        for pos in range(n_pos):
            h, nc, _ = _apply_block(blocks[f"pos{pos}"], h, cfg, pos,
                                    mode="prefill", image_embeds=image_embeds)
            if nc is not None:
                new_caches_p[f"pos{pos}"] = nc
        return h, new_caches_p

    h, caches = jax.lax.scan(period_fn, h, params["blocks"])
    return _unembed(params, cfg, h), caches


def decode_step(params, cfg, token, caches, pos, image_embeds=None,
                embeddings=None):
    """One token: token [B,1] (or embeddings [B,1,D]) + caches → logits [B,V].

    ``pos`` is a traced scalar: the write offset into the KV caches / the
    RoPE position.  Cache leaves are [n_periods, ...] stacks threaded
    through the period scan.
    """
    h = _embed(params, cfg, token, embeddings)
    if image_embeds is not None:
        image_embeds = image_embeds.astype(cfg.act_dtype)
    pos = jnp.asarray(pos)
    # scalar pos → shared position [1]; vector pos [B] → per-slot [B, 1]
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    h, new_caches, _ = _scan_periods(params, cfg, h, mode="decode",
                                     caches=caches, cache_pos=pos,
                                     image_embeds=image_embeds,
                                     positions=positions)
    logits = _unembed(params, cfg, h)[:, 0]
    return logits, new_caches
