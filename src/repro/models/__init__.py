from . import layers, moe, ssm, transformer, model_zoo
from .transformer import init_lm, forward, lm_loss, prefill, decode_step
from .model_zoo import input_specs, cache_struct, init_cache, count_params
