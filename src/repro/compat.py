"""Forward-compatibility shims for older jax runtimes.

The sources and tests target the current jax API surface:

  · ``jax.shard_map`` (with the ``check_vma`` kwarg)
  · ``jax.sharding.AxisType``
  · ``jax.make_mesh(..., axis_types=...)``

Older jaxlib builds (e.g. the 0.4.x CPU wheel in the test container) predate
all three.  Importing this module installs small forwarding shims onto the
``jax`` namespace — idempotent, and a no-op on a current jax.  Import it
before touching those APIs (tests do this via ``tests/conftest.py``).
"""
from __future__ import annotations

import inspect

import jax


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType:  # stand-in enum; pre-AxisType meshes are untyped
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"
        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "make_mesh"):
        import numpy as _np

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types  # untyped meshes only on this jax
            n = int(_np.prod(axis_shapes))
            devs = list(devices) if devices is not None else jax.devices()[:n]
            return jax.sharding.Mesh(
                _np.asarray(devs).reshape(axis_shapes), axis_names)

        jax.make_mesh = make_mesh
    elif "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types  # untyped meshes only on this jax
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                      **kwargs):
            return _shard_map(f, mesh, in_specs, out_specs,
                              check_rep=check_vma, **kwargs)

        jax.shard_map = shard_map


install()
