"""Batched serving: prefill + decode with slot-based continuous batching.

``Server`` owns a fixed batch of ``n_slots`` sequences with one shared
padded KV cache; finished slots are refilled from the request queue without
stalling the others (continuous batching at slot granularity — the decode
step shape never changes, so XLA compiles exactly two programs: prefill and
decode).

Sampling: greedy or temperature; per-slot EOS/len stop.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer, model_zoo


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 = greedy
    rid: int = 0


class Server:
    def __init__(self, params, cfg, *, n_slots: int = 4, max_seq: int = 512,
                 eos_id: int | None = None, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.rng = np.random.default_rng(seed)

        self._decode = jax.jit(
            lambda p, tok, caches, pos: transformer.decode_step(
                p, cfg, tok, caches, pos))
        self._prefill = jax.jit(
            lambda p, tok: transformer.prefill(p, cfg, tokens=tok))
        self.caches = model_zoo.init_cache(cfg, n_slots, max_seq)

    # -- single-sequence prefill into a slot (recompute-simple; a production
    #    server would batch prefills — noted in DESIGN.md) --
    def _fill_slot(self, slot: int, prompt: list[int]):
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, caches = self._prefill(self.params, toks)
        # splice this sequence's prefill caches into the batch cache at slot
        def splice(batch_leaf, one_leaf):
            if batch_leaf.ndim >= 3 and one_leaf.shape[1] == 1:
                # [P, B, S, ...] ← [P, 1, s, ...] at (slot, 0)
                start = (0, slot) + (0,) * (batch_leaf.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    batch_leaf, one_leaf.astype(batch_leaf.dtype), start)
            return batch_leaf
        self.caches = jax.tree.map(splice, self.caches, caches)
        last = logits[:, -1]
        return last[0]

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return int(jnp.argmax(logits))
        probs = np.asarray(jax.nn.softmax(logits.astype(jnp.float32)
                                          / temperature), np.float64)
        probs = probs / probs.sum()
        return int(self.rng.choice(probs.shape[0], p=probs))

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion; returns {rid: generated tokens}."""
        queue = list(requests)
        slots: list[dict | None] = [None] * self.n_slots
        done: dict[int, list[int]] = {}

        def admit():
            for i in range(self.n_slots):
                if slots[i] is None and queue:
                    req = queue.pop(0)
                    last_logits = self._fill_slot(i, req.prompt)
                    tok = self._sample(last_logits, req.temperature)
                    slots[i] = {"req": req, "pos": len(req.prompt),
                                "out": [tok], "next": tok}

        admit()
        step_tokens = np.zeros((self.n_slots, 1), np.int32)
        step_pos = np.zeros((self.n_slots,), np.int32)
        while any(s is not None for s in slots):
            # per-slot positions: every active slot decodes at its own offset
            # (vector-pos decode path); idle slots write harmlessly at 0 and
            # are overwritten by the next prefill splice.
            active = [i for i, s in enumerate(slots) if s is not None]
            for i in range(self.n_slots):
                step_tokens[i, 0] = slots[i]["next"] if slots[i] else 0
                step_pos[i] = slots[i]["pos"] if slots[i] else 0
            logits, self.caches = self._decode(
                self.params, jnp.asarray(step_tokens), self.caches,
                jnp.asarray(step_pos))
            for i in active:
                s = slots[i]
                tok = self._sample(logits[i], s["req"].temperature)
                s["out"].append(tok)
                s["next"] = tok
                s["pos"] += 1
                hit_eos = self.eos_id is not None and tok == self.eos_id
                if (len(s["out"]) >= s["req"].max_new_tokens or hit_eos
                        or s["pos"] >= self.max_seq - 1):
                    done[s["req"].rid] = s["out"]
                    slots[i] = None
            admit()
        return done
