"""Batched serving: prefill + decode with slot-based continuous batching.

``Server`` owns a fixed batch of ``n_slots`` sequences with one shared
padded KV cache; finished slots are refilled from the request queue without
stalling the others (continuous batching at slot granularity — the decode
step shape never changes, so XLA compiles exactly two programs: prefill and
decode).

Sampling: greedy or temperature; per-slot EOS/len stop.  The EOS token is a
stop signal, not content: it is never included in the returned tokens.

Admission contract (shared with the cluster assignment server): requests are
validated *before* any device work — an empty prompt, a prompt with
``len(prompt) >= max_seq`` (the KV-cache splice would silently clamp and
corrupt the cache), or ``max_new_tokens < 1`` raises ``ValueError`` naming
the offending request.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer, model_zoo


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 = greedy
    rid: int = 0


class Server:
    def __init__(self, params, cfg, *, n_slots: int = 4, max_seq: int = 512,
                 eos_id: int | None = None, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.rng = np.random.default_rng(seed)

        self._decode = jax.jit(
            lambda p, tok, caches, pos: transformer.decode_step(
                p, cfg, tok, caches, pos))
        self._prefill = jax.jit(
            lambda p, tok: transformer.prefill(p, cfg, tokens=tok))
        self.caches = model_zoo.init_cache(cfg, n_slots, max_seq)

    # -- single-sequence prefill into a slot (recompute-simple; a production
    #    server would batch prefills — noted in DESIGN.md) --
    def _fill_slot(self, slot: int, prompt: list[int]):
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, caches = self._prefill(self.params, toks)
        # splice this sequence's prefill caches into the batch cache at slot
        def splice(batch_leaf, one_leaf):
            if batch_leaf.ndim >= 3 and one_leaf.shape[1] == 1:
                # [P, B, S, ...] ← [P, 1, s, ...] at (slot, 0)
                start = (0, slot) + (0,) * (batch_leaf.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    batch_leaf, one_leaf.astype(batch_leaf.dtype), start)
            return batch_leaf
        self.caches = jax.tree.map(splice, self.caches, caches)
        last = logits[:, -1]
        return last[0]

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return int(jnp.argmax(logits))
        probs = np.asarray(jax.nn.softmax(logits.astype(jnp.float32)
                                          / temperature), np.float64)
        probs = probs / probs.sum()
        return int(self.rng.choice(probs.shape[0], p=probs))

    def admit_check(self, req: Request) -> None:
        """Validate a request before any device work (loud admission).

        Raises ``ValueError`` for prompts the cache splice cannot hold —
        the old behaviour let ``dynamic_update_slice`` clamp the start
        index and silently corrupt neighbouring slots' caches.
        """
        n = len(req.prompt)
        if n < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if n >= self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt length {n} >= max_seq "
                f"{self.max_seq} — the KV cache cannot hold it")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}")

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion; returns {rid: generated tokens}.

        The EOS token (when configured) terminates a sequence and is
        stripped — returned token lists never contain ``eos_id``.
        """
        for req in requests:
            self.admit_check(req)
        queue = list(requests)
        slots: list[dict | None] = [None] * self.n_slots
        done: dict[int, list[int]] = {}

        def admit():
            for i in range(self.n_slots):
                while slots[i] is None and queue:
                    req = queue.pop(0)
                    last_logits = self._fill_slot(i, req.prompt)
                    tok = self._sample(last_logits, req.temperature)
                    # the prefill-sampled token gets the same stop checks
                    # as decode steps: EOS ends (and is stripped from) the
                    # output, and max_new_tokens==1 completes immediately
                    if self.eos_id is not None and tok == self.eos_id:
                        done[req.rid] = []
                        continue
                    if req.max_new_tokens <= 1:
                        done[req.rid] = [tok]
                        continue
                    slots[i] = {"req": req, "pos": len(req.prompt),
                                "out": [tok], "next": tok}

        admit()
        step_tokens = np.zeros((self.n_slots, 1), np.int32)
        step_pos = np.zeros((self.n_slots,), np.int32)
        while any(s is not None for s in slots):
            # per-slot positions: every active slot decodes at its own offset
            # (vector-pos decode path); idle slots write harmlessly at 0 and
            # are overwritten by the next prefill splice.
            active = [i for i, s in enumerate(slots) if s is not None]
            for i in range(self.n_slots):
                step_tokens[i, 0] = slots[i]["next"] if slots[i] else 0
                step_pos[i] = slots[i]["pos"] if slots[i] else 0
            logits, self.caches = self._decode(
                self.params, jnp.asarray(step_tokens), self.caches,
                jnp.asarray(step_pos))
            for i in active:
                s = slots[i]
                tok = self._sample(logits[i], s["req"].temperature)
                s["pos"] += 1
                if self.eos_id is not None and tok == self.eos_id:
                    # stop signal, not content — do not append
                    done[s["req"].rid] = s["out"]
                    slots[i] = None
                    continue
                s["out"].append(tok)
                s["next"] = tok
                if (len(s["out"]) >= s["req"].max_new_tokens
                        or s["pos"] >= self.max_seq - 1):
                    done[s["req"].rid] = s["out"]
                    slots[i] = None
            admit()
        return done
