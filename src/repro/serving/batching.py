"""Request batching for the cluster assignment server.

The LM server (``serve_loop``) holds its batch shape fixed with slots; the
assignment server holds it fixed with *buckets*: drained request batches
are packed greedily (arrival order) up to the largest bucket, padded to the
smallest bucket that holds them (``kernels.layout.bucket_for``), and the
padding rows are absorbed by the ops' mask operand.  XLA therefore
compiles one program per (model, bucket) — the recompile-count claim
``BENCH_serve_cluster.json`` tracks.

Admission mirrors the LM server's contract (``Server.admit_check``): a
malformed request raises ``ValueError`` naming the offender *before* any
device work — empty batches, wrong feature width, unknown model keys and
batches larger than the largest bucket never enter the queue.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class AssignRequest:
    """Label ``x`` [n, D] under a registered model: the high-traffic path."""
    x: Any
    model_key: str
    rid: int = 0


@dataclasses.dataclass
class FitRequest:
    """Small incremental fit: advance the registered model's parameters on
    a fresh batch (the artifact's own engine regime, its own h* stop)."""
    x: Any
    model_key: str
    rid: int = 0


def pack_batches(requests, max_rows: int):
    """Greedily pack requests into groups of ≤ ``max_rows`` total rows,
    preserving arrival order (a served batch never reorders the queue)."""
    groups: list[list] = []
    cur: list = []
    cur_rows = 0
    for r in requests:
        n = int(np.shape(r.x)[0])
        if cur and cur_rows + n > max_rows:
            groups.append(cur)
            cur, cur_rows = [], 0
        cur.append(r)
        cur_rows += n
    if cur:
        groups.append(cur)
    return groups


class ServeMetrics:
    """Per-model latency/throughput accounting (the D-SPACE4Cloud-style
    capacity numbers a cost planner consumes — see PAPERS.md)."""

    def __init__(self):
        self._lat: dict[str, list[float]] = {}
        self._points: dict[str, int] = {}
        self._requests: dict[str, int] = {}

    def record(self, key: str, latency_s: float, points: int,
               requests: int) -> None:
        self._lat.setdefault(key, []).append(latency_s)
        self._points[key] = self._points.get(key, 0) + points
        self._requests[key] = self._requests.get(key, 0) + requests

    def summary(self) -> dict[str, dict]:
        out = {}
        for key, lats in self._lat.items():
            arr = np.asarray(lats, np.float64)
            wall = float(arr.sum())
            out[key] = {
                "batches": int(arr.size),
                "requests": self._requests[key],
                "points": self._points[key],
                "p50_latency_ms": float(np.percentile(arr, 50) * 1e3),
                "p99_latency_ms": float(np.percentile(arr, 99) * 1e3),
                "throughput_points_per_s":
                    self._points[key] / wall if wall > 0 else float("inf"),
                "qps":
                    self._requests[key] / wall if wall > 0 else float("inf"),
            }
        return out
