"""Clustering-as-a-service: a continuous-batching assignment server.

This is the high-traffic side of the paper's economics (§5.4): models are
fitted rarely (``launch/cluster.py``), then *applied* constantly.  The
server generalises ``serve_loop``'s slot discipline — keep the compiled
shape set closed, refill from a queue — to clustering workloads:

  · :class:`ModelRegistry` admits fitted ``(params, LongTailModel)``
    artifacts (``core.artifacts.ClusterArtifact``), keyed by the
    provenance fingerprint from ``core.longtail_train.config_fingerprint``.
    Admission is *strict*: ``EngineConfig.from_longtail(strict=True)``
    raises :class:`~repro.core.engine.ProvenanceMismatchError` when the
    serving regime does not match the regime the stop-model was fitted
    under — a mis-calibrated h* must never reach production traffic.

  · :class:`ClusterServer` drains a queue of assignment batches (plus
    small incremental minibatch-fit jobs) into fixed padded batch-bucket
    shapes (``kernels.layout.bucket_for``), so XLA compiles one program
    per (model, bucket).  The hot path runs through the backend-dispatched
    assignment ops (``kernels.dispatch``: the artifact's pinned
    ``kernel_backend`` when it was fitted with ``use_kernel``, the ``xla``
    reference otherwise), with the ops' mask operand absorbing the bucket
    padding — padded rows are labelled −1 and dropped before the response
    is split back per request.

Request admission mirrors ``serve_loop.Server.admit_check``: malformed
batches (empty, wrong feature width, larger than the largest bucket,
unknown model, duplicate rid) raise ``ValueError`` before any device work.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifacts import ClusterArtifact, fingerprint_key
from repro.core.engine import (EngineConfig, _fit_chunked, get_algorithm)
from repro.core.longtail_train import config_fingerprint
from repro.kernels import layout
from repro.serving.batching import (AssignRequest, FitRequest, ServeMetrics,
                                    pack_batches)


def _serving_kwargs(prov: dict | None, overrides: dict | None) -> dict:
    """EngineConfig kwargs for serving an artifact: its stamped harvest
    regime, with explicit ``overrides`` on top.  Overriding to full mode
    drops the stamped minibatch knobs so the mismatch surfaces as a
    ProvenanceMismatchError (the admission contract), not as
    EngineConfig's stray-knob ValueError."""
    kw: dict = {}
    if prov:
        kw = {f: prov[f] for f in EngineConfig.MATCHED_FIELDS if f in prov}
        if "chunks" in prov:
            kw["chunks"] = prov["chunks"]
    if overrides:
        kw.update(overrides)
    if kw.get("mode", "full") == "full":
        for f, default in (("batch_chunks", 0), ("decay", 1.0),
                           ("seed", 0), ("ema", 0.0)):
            kw[f] = default
    if not kw.get("use_kernel", False):
        kw.pop("kernel_backend", None)
    return kw


@dataclasses.dataclass
class _Entry:
    """One registered model: device params + its compiled programs."""
    key: str
    artifact: ClusterArtifact
    config: EngineConfig
    params: Any                  # device copy, advanced by fit jobs
    assign: Any                  # jit'd (xp, mask, params) → (labels, obj)
    fit: Any                     # jit'd (xc, mask, params, h*) → EngineResult
    backend: str


class ModelRegistry:
    """Fitted artifacts keyed by ``name@fingerprint``; strict admission."""

    def __init__(self, *, devices: int = 1, fit_steps: int = 20,
                 overrides: dict | None = None):
        self.devices = devices
        self.fit_steps = fit_steps
        self.overrides = overrides
        self._entries: dict[str, _Entry] = {}

    def register(self, artifact: ClusterArtifact,
                 overrides: dict | None = None) -> str:
        """Admit an artifact; returns its registry key.

        Raises ``ProvenanceMismatchError`` when the serving configuration
        (stamped regime + overrides) mismatches the regime the artifact's
        stop-model was fitted under — rejected loudly, never registered.
        """
        ov = dict(self.overrides or {})
        ov.update(overrides or {})
        kw = _serving_kwargs(artifact.model.engine_config, ov)
        cfg = EngineConfig.from_longtail(
            artifact.model, artifact.desired_accuracy, strict=True, **kw)
        key = (f"{artifact.name}"
               f"@{fingerprint_key(config_fingerprint(cfg, self.devices))}")
        if key in self._entries:
            raise ValueError(f"model {key!r} already registered")
        alg = get_algorithm(artifact.algorithm)
        backend = cfg.kernel_backend if cfg.use_kernel else "xla"
        params = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32),
                              artifact.params)

        def _assign(xp, mask, p):
            labels, stats = alg.kernel_chunk_stats(xp, mask, p,
                                                   backend=backend)
            return labels, alg.objective(stats)

        fit_cfg = dataclasses.replace(
            cfg, trace=False, max_iters=self.fit_steps)

        def _fit(xc, mask, p, h_star):
            return _fit_chunked(xc, mask, p, h_star, alg=alg, config=fit_cfg)

        self._entries[key] = _Entry(
            key=key, artifact=artifact, config=cfg, params=params,
            assign=jax.jit(_assign), fit=jax.jit(_fit), backend=backend)
        return key

    def __getitem__(self, key: str) -> _Entry:
        try:
            return self._entries[key]
        except KeyError:
            raise ValueError(
                f"unknown model {key!r}; registered: "
                f"{sorted(self._entries)}") from None

    def keys(self):
        return sorted(self._entries)


class ClusterServer:
    """Queue → bucket-padded batches → dispatched assignment ops."""

    def __init__(self, registry: ModelRegistry, *,
                 buckets=layout.DEFAULT_BUCKETS):
        self.registry = registry
        self.buckets = tuple(sorted(buckets))
        self._queue: list = []
        self._pending_rids: set = set()
        self.metrics = ServeMetrics()

    # ---- admission (serve_loop.Server.admit_check's contract) ------------
    def submit(self, req) -> None:
        if not isinstance(req, (AssignRequest, FitRequest)):
            raise TypeError(f"unknown request type {type(req).__name__}")
        entry = self.registry[req.model_key]
        x = np.asarray(req.x, np.float32)
        if x.ndim != 2 or x.shape[0] < 1:
            raise ValueError(
                f"request {req.rid}: batch must be [n >= 1, d]; got shape "
                f"{x.shape}")
        if x.shape[1] != entry.artifact.d:
            raise ValueError(
                f"request {req.rid}: feature width {x.shape[1]} != model "
                f"{req.model_key!r} width {entry.artifact.d}")
        if x.shape[0] > self.buckets[-1]:
            raise ValueError(
                f"request {req.rid}: batch of {x.shape[0]} rows exceeds "
                f"the largest bucket {self.buckets[-1]} — split it")
        if req.rid in self._pending_rids:
            raise ValueError(f"request {req.rid}: rid already pending")
        self._pending_rids.add(req.rid)
        self._queue.append(dataclasses.replace(req, x=x))

    # ---- compile-shape bookkeeping ---------------------------------------
    def warmup(self, model_key: str, buckets=None) -> None:
        """Pre-compile the assign program for each bucket (zero-mask dummy
        batches) so drain latencies measure steady-state serving."""
        entry = self.registry[model_key]
        for b in (buckets or self.buckets):
            xp = jnp.zeros((b, entry.artifact.d), jnp.float32)
            mask = jnp.zeros((b,), jnp.float32)
            jax.block_until_ready(entry.assign(xp, mask, entry.params))

    def compiled_programs(self) -> dict[str, dict[str, int]]:
        """{model key: {assign/fit: jit cache entries}} — the recompile
        probe: assign must stay ≤ the number of distinct buckets served."""
        return {k: {"assign": int(self.registry[k].assign._cache_size()),
                    "fit": int(self.registry[k].fit._cache_size())}
                for k in self.registry.keys()}

    # ---- the serve loop --------------------------------------------------
    def _chunked_bucket(self, x: np.ndarray, config: EngineConfig):
        """Bucket-pad a fit batch and lay it out as the engine's [C, P, D]
        chunked layout; the combined mask zeroes both paddings."""
        bucket = layout.bucket_for(x.shape[0], self.buckets)
        xp, valid = layout.pad_to_bucket(x, bucket)
        xc, m = layout.chunk_points(xp, config.chunks)
        mask = m * valid.reshape(m.shape)
        return xc, mask

    def _serve_assign_group(self, entry: _Entry, group, results) -> None:
        xs = [r.x for r in group]
        total = sum(x.shape[0] for x in xs)
        bucket = layout.bucket_for(total, self.buckets)
        xp, mask = layout.pad_to_bucket(np.concatenate(xs, axis=0), bucket)
        t0 = time.perf_counter()
        labels, _obj = entry.assign(xp, mask, entry.params)
        labels = np.asarray(jax.block_until_ready(labels))
        dt = time.perf_counter() - t0
        self.metrics.record(entry.key, dt, total, len(group))
        off = 0
        for r in group:
            n = r.x.shape[0]
            results[r.rid] = labels[off:off + n].copy()
            off += n

    def _serve_fit(self, entry: _Entry, req: FitRequest, results) -> None:
        xc, mask = self._chunked_bucket(req.x, entry.config)
        t0 = time.perf_counter()
        res = entry.fit(xc, mask, entry.params,
                        jnp.asarray(entry.config.h_star, jnp.float32))
        res = jax.block_until_ready(res)
        dt = time.perf_counter() - t0
        self.metrics.record(f"{entry.key}#fit", dt, req.x.shape[0], 1)
        entry.params = res.params      # the model advances in place
        results[req.rid] = {"objective": float(res.objective),
                            "n_iters": int(res.n_iters)}

    def drain(self) -> dict:
        """Serve everything queued; returns {rid: labels [n] | fit result}.

        Assignment batches are grouped per model and packed (arrival
        order) up to the largest bucket; fit jobs run one at a time —
        they are rare by construction (the paper's whole premise).
        """
        queue, self._queue = self._queue, []
        results: dict = {}
        by_model: dict[str, list] = {}
        for req in queue:
            by_model.setdefault(req.model_key, []).append(req)
        for key in sorted(by_model):
            entry = self.registry[key]
            assigns = [r for r in by_model[key]
                       if isinstance(r, AssignRequest)]
            fits = [r for r in by_model[key] if isinstance(r, FitRequest)]
            for group in pack_batches(assigns, self.buckets[-1]):
                self._serve_assign_group(entry, group, results)
            for req in fits:
                self._serve_fit(entry, req, results)
        self._pending_rids -= set(results)
        return results
