from .serve_loop import Server, Request
from .batching import AssignRequest, FitRequest, ServeMetrics, pack_batches
from .cluster_server import ClusterServer, ModelRegistry
