"""Repo-specific source lint (AST level) — AST001/AST002/AST003/AST004.

These are contracts the graph passes can't see (they hold at the source
layer, before tracing):

  AST001  kernel entry points in ``kernels/*/ops.py`` whose first
          parameter is the points array ``x`` must accept ``mask=`` —
          padding, sharding and minibatch draws all compose through the
          mask operand, on every backend (``flash_attention``'s ``q``
          leading parameter is naturally exempt);
  AST002  collective calls must not hard-code axis names as string
          literals — graphs take the axis from config/mesh so one
          program serves every mesh layout (warning severity: literal
          names are legitimate directly under the shard_map facades);
  AST003  no Python/numpy RNG calls inside traced functions (decorated
          with jit, passed to lax control flow / shard_map / vmap, or
          nested in one) — host randomness bakes ONE draw into the
          compiled graph as a constant;
  AST004  no hard-coded integer block shapes (``block_n=256`` and
          friends) at kernel call sites — block resolution belongs to
          ``layout.tile_policy()`` / the autotune cache, and a literal
          at the call site silently bypasses both (plus the Triton
          power-of-two constraint).  ``TilePolicy(...)`` constructor
          calls are exempt: they ARE the hand-picked defaults.

Any finding can be waived at the flagged line (or the line above) with
``# repro-lint: disable=AST002`` (comma-separated ids, or a bare
``disable`` to waive every rule on that line).
"""
from __future__ import annotations

import ast
import pathlib
import re

from repro.analysis.report import Finding

COLLECTIVE_FNS = frozenset({
    "psum", "pmax", "pmin", "pmean", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "reduce_scatter", "psum_scatter",
    "axis_index"})
_TRACING_FNS = frozenset({
    "scan", "while_loop", "fori_loop", "cond", "switch", "map",
    "associated_scan", "shard_map", "vmap", "pmap", "jit", "grad",
    "value_and_grad", "checkpoint", "remat", "custom_jvp", "custom_vjp"})
_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable(?:=([\w,\s]+))?")


def _suppressed(lines: list[str], lineno: int, rule: str) -> bool:
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = _SUPPRESS_RE.search(lines[ln - 1])
            if m:
                ids = m.group(1)
                if ids is None or rule in {t.strip() for t in ids.split(",")}:
                    return True
    return False


def _fn_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _has_str_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, str)
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_has_str_literal(e) for e in node.elts)
    return False


# ------------------------------------------------------------------ AST001

def _check_kernel_mask(tree: ast.Module, relpath: str,
                       lines: list[str]) -> list[Finding]:
    findings = []
    for node in tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name.startswith("_"):
            continue
        args = node.args
        if not args.args or args.args[0].arg != "x":
            continue
        names = {a.arg for a in list(args.args) + list(args.kwonlyargs)}
        if "mask" not in names and \
                not _suppressed(lines, node.lineno, "AST001"):
            findings.append(Finding(
                "AST001", f"{relpath}:{node.lineno}",
                f"kernel entry point '{node.name}' takes the points array "
                "but has no mask= parameter — padding/sharding/minibatch "
                "composition requires the mask operand"))
    return findings


# ------------------------------------------------------------------ AST002

def _check_axis_literals(tree: ast.Module, relpath: str,
                         lines: list[str]) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or \
                _fn_name(node) not in COLLECTIVE_FNS:
            continue
        literal = any(_has_str_literal(a) for a in node.args) or any(
            kw.arg in ("axis_name", "axes") and _has_str_literal(kw.value)
            for kw in node.keywords)
        if literal and not _suppressed(lines, node.lineno, "AST002"):
            findings.append(Finding(
                "AST002", f"{relpath}:{node.lineno}",
                f"collective '{_fn_name(node)}' hard-codes its axis name "
                "as a string literal — take it from config/mesh "
                "(cfg.axis_name) so the graph serves every mesh layout"))
    return findings


# ------------------------------------------------------------------ AST003

_RNG_MODULES = ("random", "np.random", "numpy.random")


def _dotted(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _traced_functions(tree: ast.Module) -> set[ast.AST]:
    """Function nodes that end up inside a traced graph: jit-decorated,
    passed (by name or as a lambda) to lax control flow / shard_map /
    vmap, or nested inside one of those."""
    by_name: dict[str, list[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)

    traced: set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                names = {n.attr for n in ast.walk(dec)
                         if isinstance(n, ast.Attribute)}
                names |= {n.id for n in ast.walk(dec)
                          if isinstance(n, ast.Name)}
                if "jit" in names:
                    traced.add(node)
        elif isinstance(node, ast.Call) and _fn_name(node) in _TRACING_FNS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Lambda):
                    traced.add(arg)
                elif isinstance(arg, ast.Name):
                    traced.update(by_name.get(arg.id, ()))

    # closure: defs nested inside a traced function are traced
    grew = True
    while grew:
        grew = False
        for fn in list(traced):
            for sub in ast.walk(fn):
                if sub is not fn and isinstance(
                        sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)) and sub not in traced:
                    traced.add(sub)
                    grew = True
    return traced


def _check_rng_in_traced(tree: ast.Module, relpath: str,
                         lines: list[str]) -> list[Finding]:
    findings = []
    seen_lines: set[int] = set()
    for fn in _traced_functions(tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            hit = any(dotted.startswith(mod + ".") for mod in _RNG_MODULES)
            if hit and node.lineno not in seen_lines and \
                    not _suppressed(lines, node.lineno, "AST003"):
                seen_lines.add(node.lineno)
                name = getattr(fn, "name", "<lambda>")
                findings.append(Finding(
                    "AST003", f"{relpath}:{node.lineno}",
                    f"'{dotted}' call inside traced function '{name}' — "
                    "host RNG runs once at trace time and bakes a single "
                    "draw into the compiled graph; use jax.random with a "
                    "threaded key"))
    return findings


# ------------------------------------------------------------------ AST004

_BLOCK_KWARGS = frozenset({"block_n", "block_q", "block_k", "block_rows"})


def _check_block_literals(tree: ast.Module, relpath: str,
                          lines: list[str]) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or _fn_name(node) == "TilePolicy":
            continue
        for kw in node.keywords:
            if kw.arg in _BLOCK_KWARGS and \
                    isinstance(kw.value, ast.Constant) and \
                    isinstance(kw.value.value, int) and \
                    not isinstance(kw.value.value, bool) and \
                    not _suppressed(lines, kw.value.lineno, "AST004"):
                findings.append(Finding(
                    "AST004", f"{relpath}:{kw.value.lineno}",
                    f"'{_fn_name(node)}' call hard-codes {kw.arg}="
                    f"{kw.value.value} — block shapes resolve through "
                    "layout.tile_policy() / the autotune cache; a literal "
                    "here bypasses backend alignment (incl. the Triton "
                    "power-of-two rule) and pins every backend to one "
                    "shape"))
    return findings


# ------------------------------------------------------------------ driver

def check_source(source: str, relpath: str) -> list[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("AST001", f"{relpath}:{e.lineno or 0}",
                        f"unparseable source: {e.msg}")]
    lines = source.splitlines()
    findings = []
    parts = pathlib.PurePath(relpath).parts
    if "kernels" in parts and parts[-1] == "ops.py":
        findings += _check_kernel_mask(tree, relpath, lines)
    findings += _check_axis_literals(tree, relpath, lines)
    findings += _check_rng_in_traced(tree, relpath, lines)
    findings += _check_block_literals(tree, relpath, lines)
    return findings


def check_paths(root, paths=None) -> list[Finding]:
    """Run the AST rules over ``paths`` (default: every ``*.py`` under
    ``root``), reporting locations relative to ``root``'s parent."""
    root = pathlib.Path(root)
    files = sorted(root.rglob("*.py")) if paths is None \
        else [pathlib.Path(p) for p in paths]
    findings = []
    for f in files:
        try:
            rel = f.relative_to(root.parent)
        except ValueError:
            rel = f
        findings += check_source(f.read_text(), str(rel))
    return findings
