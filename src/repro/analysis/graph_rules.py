"""Jaxpr and HLO rule passes over traced fit graphs.

All passes take a traced (NOT executed) ``ClosedJaxpr`` — obtained from
``jax.make_jaxpr`` over the shard_map'd fit drivers — and return
:class:`~repro.analysis.report.Finding` lists.  The central analysis is
*shard uniformity*: a value is uniform when every shard provably holds
the same value (replicated inputs, constants, and the results of
full-axis ``psum``/``pmax``/``pmin``/``all_gather`` are uniform;
shard_map-sharded inputs, ``axis_index``, ``ppermute`` and
``reduce_scatter`` results are not; elementwise ops preserve uniformity
of their inputs; loop carries take a monotone fixpoint).  The SPMD
deadlock class (PR 7) is exactly a *control decision that gates
collectives going non-uniform*:

  · a ``while_loop`` whose body/cond issues collectives must have a
    provably uniform exit predicate — else trip counts can diverge
    across shards and one shard blocks in a collective its peers never
    enter (GC001);
  · ``cond``/``switch`` branches with *different* collective sequences
    are only safe under a uniform predicate — shard-varying branch
    selection with divergent sequences deadlocks (GC001).

``lax.scan``/``fori_loop`` static trip counts are uniform by
construction, so collectives inside scans are fine.
"""
from __future__ import annotations

from repro.analysis.report import Finding

# jaxpr primitive names (jax 0.4.x)
UNIFORMING_COLLECTIVES = frozenset({"psum", "pmax", "pmin", "all_gather"})
OTHER_COLLECTIVES = frozenset({
    "ppermute", "pbroadcast", "all_to_all", "reduce_scatter", "pgather",
    "psum_scatter"})
COLLECTIVE_PRIMS = UNIFORMING_COLLECTIVES | OTHER_COLLECTIVES
NONUNIFORM_PRIMS = frozenset({
    "axis_index", "ppermute", "all_to_all", "reduce_scatter", "pgather",
    "psum_scatter"})
HOST_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "infeed", "outfeed", "host_callback_call"})

_F64_DTYPES = ("float64", "complex128")


# --------------------------------------------------------------- structure

def as_open(jaxpr):
    """ClosedJaxpr | Jaxpr → the open Jaxpr."""
    return getattr(jaxpr, "jaxpr", jaxpr)


def sub_jaxprs(eqn):
    """Every sub-jaxpr in an equation's params, in declaration order."""
    for key in sorted(eqn.params):
        val = eqn.params[key]
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                yield key, as_open(v)


def iter_eqns(jaxpr, path=""):
    """Depth-first (eqn, path) over a jaxpr and every sub-jaxpr."""
    for eqn in as_open(jaxpr).eqns:
        name = eqn.primitive.name
        yield eqn, path
        for key, sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub, f"{path}/{name}.{key}")


def has_collectives(jaxpr) -> bool:
    return any(e.primitive.name in COLLECTIVE_PRIMS
               for e, _ in iter_eqns(jaxpr))


def _axes_of(params) -> tuple:
    ax = params.get("axes", params.get("axis_name", ()))
    if not isinstance(ax, tuple):
        ax = (ax,)
    return tuple(str(a) for a in ax)


def collective_signature(eqn) -> tuple:
    """(op, axes, extra-params, result shapes+dtypes) — two collectives
    with equal signatures pair up across shards."""
    extras = tuple(sorted(
        (k, str(v)) for k, v in eqn.params.items()
        if k not in ("axes", "axis_name")
        and isinstance(v, (bool, int, float, str, tuple))))
    outs = tuple((str(v.aval.dtype), tuple(v.aval.shape))
                 for v in eqn.outvars)
    return (eqn.primitive.name, _axes_of(eqn.params), extras, outs)


def collective_sequence(jaxpr) -> tuple:
    """Structural collective schedule of a jaxpr: flat signatures, with
    loops/branches as nested markers so ('while', …) ≠ an unrolled body."""
    seq = []
    for eqn in as_open(jaxpr).eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            seq.append(collective_signature(eqn))
        elif name == "while":
            seq.append(("while",
                        collective_sequence(eqn.params["cond_jaxpr"]),
                        collective_sequence(eqn.params["body_jaxpr"])))
        elif name == "cond":
            seq.append(("cond", tuple(collective_sequence(b)
                                      for b in eqn.params["branches"])))
        elif name == "scan":
            seq.append(("scan", eqn.params.get("length"),
                        collective_sequence(eqn.params["jaxpr"])))
        else:
            for _, sub in sub_jaxprs(eqn):
                inner = collective_sequence(sub)
                if inner:
                    seq.extend(inner)
    return tuple(seq)


def describe_signature(sig) -> str:
    if sig and sig[0] in ("while", "cond", "scan"):
        return sig[0]
    op, axes, _, outs = sig
    shapes = ",".join(f"{d}{list(s)}" for d, s in outs)
    return f"{op}[axis={'/'.join(axes)}; {shapes}]"


# ----------------------------------------------------- uniformity analysis

class _UniformWalker:
    """Propagates shard-uniformity through a jaxpr, emitting GC001
    findings at every control construct that gates collectives on a
    non-uniform value."""

    def __init__(self, where: str, config: str | None):
        self.where = where
        self.config = config
        self.findings: list[Finding] = []

    def _finding(self, path, msg):
        self.findings.append(Finding(
            "GC001", f"{self.where}{path}", msg, config=self.config))

    def run(self, jaxpr, in_uniform, path="") -> list[bool]:
        """Returns uniformity of the jaxpr's outputs."""
        jx = as_open(jaxpr)
        env: dict = {}

        def write(var, val):
            env[var] = bool(val)

        def read(atom):
            # Literals and constvars are baked into the program: uniform.
            return env.get(atom, True) if hasattr(atom, "aval") \
                and not hasattr(atom, "val") else True

        if len(in_uniform) != len(jx.invars):
            in_uniform = [True] * len(jx.invars)
        for var, u in zip(jx.invars, in_uniform):
            write(var, u)
        for var in jx.constvars:
            write(var, True)

        for eqn in jx.eqns:
            name = eqn.primitive.name
            ins = [read(v) for v in eqn.invars]
            epath = f"{path}/{name}"
            if name in UNIFORMING_COLLECTIVES:
                outs = [True] * len(eqn.outvars)
            elif name in NONUNIFORM_PRIMS:
                outs = [False] * len(eqn.outvars)
            elif name == "while":
                outs = self._while(eqn, ins, epath)
            elif name == "cond":
                outs = self._cond(eqn, ins, epath)
            elif name == "scan":
                outs = self._scan(eqn, ins, epath)
            elif name == "shard_map":
                outs = self._shard_map(eqn, epath)
            else:
                sub = dict(sub_jaxprs(eqn))
                if sub and len(sub) == 1:
                    inner = next(iter(sub.values()))
                    if len(inner.invars) == len(ins):
                        outs = self.run(inner, ins, epath)
                        if len(outs) != len(eqn.outvars):
                            outs = [all(ins)] * len(eqn.outvars)
                    else:
                        outs = [all(ins)] * len(eqn.outvars)
                else:
                    outs = [all(ins)] * len(eqn.outvars)
            for var, u in zip(eqn.outvars, outs):
                write(var, u)

        return [read(v) for v in jx.outvars]

    def _while(self, eqn, ins, path):
        p = eqn.params
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        cond_consts, body_consts = ins[:cn], ins[cn:cn + bn]
        carry = list(ins[cn + bn:])
        body, cond = p["body_jaxpr"], p["cond_jaxpr"]
        # Monotone fixpoint: uniformity only ever decays.
        for _ in range(len(carry) + 1):
            probe = _UniformWalker(self.where, self.config)
            out = probe.run(body, body_consts + carry, path + ".body")
            new = [a and b for a, b in zip(carry, out)]
            if new == carry:
                break
            carry = new
        # Re-run at the fixpoint, keeping nested findings exactly once.
        body_out = self.run(body, body_consts + carry, path + ".body")
        cond_out = self.run(cond, cond_consts + carry, path + ".cond")
        if (has_collectives(body) or has_collectives(cond)) \
                and not all(cond_out):
            self._finding(
                path,
                "while_loop issues collectives but its exit predicate is "
                "not provably shard-uniform — trip counts can diverge "
                "across shards and deadlock the collective schedule "
                "(derive the predicate from psum/pmax-reduced values)")
        return [a and b for a, b in zip(carry, body_out)]

    def _cond(self, eqn, ins, path):
        pred_uniform, op_ins = ins[0], ins[1:]
        branches = eqn.params["branches"]
        seqs = [collective_sequence(b) for b in branches]
        if not pred_uniform and len(set(seqs)) > 1:
            diff = " vs ".join(
                "(" + ", ".join(describe_signature(s) for s in seq) + ")"
                for seq in seqs)
            self._finding(
                path,
                "cond branches issue divergent collective sequences "
                f"{diff} under a shard-varying predicate — shards taking "
                "different branches deadlock")
        branch_outs = [self.run(b, list(op_ins), f"{path}.b{i}")
                       for i, b in enumerate(branches)]
        n = len(eqn.outvars)
        return [pred_uniform and all(bo[i] if i < len(bo) else True
                                     for bo in branch_outs)
                for i in range(n)]

    def _scan(self, eqn, ins, path):
        p = eqn.params
        nc, ncar = p["num_consts"], p["num_carry"]
        consts, carry = ins[:nc], list(ins[nc:nc + ncar])
        xs = ins[nc + ncar:]
        body = p["jaxpr"]
        n_ys = len(eqn.outvars) - ncar
        ys = [True] * n_ys
        for _ in range(ncar + 1):
            probe = _UniformWalker(self.where, self.config)
            out = probe.run(body, consts + carry + list(xs), path + ".body")
            new = [a and b for a, b in zip(carry, out[:ncar])]
            if new == carry:
                ys = [a and b for a, b in zip(ys, out[ncar:])]
                break
            carry = new
        out = self.run(body, consts + carry + list(xs), path + ".body")
        ys = [a and b for a, b in zip(ys, out[ncar:])]
        return carry + ys

    def _shard_map(self, eqn, path):
        p = eqn.params
        in_names = p.get("in_names")
        inner = p["jaxpr"]
        n_in = len(as_open(inner).invars)
        if in_names is None:
            ins = [False] * n_in
        else:
            # {} = replicated operand → uniform; any named axis → sharded
            ins = [not dict(names) for names in in_names]
            ins += [False] * (n_in - len(ins))
        outs = self.run(inner, ins, path)
        n = len(eqn.outvars)
        if len(outs) != n:
            outs = [False] * n
        return outs


# ------------------------------------------------------------------ rules

def check_collective_uniformity(jaxpr, where: str,
                                config: str | None = None) -> list[Finding]:
    """GC001 — no shard-divergent control over collectives."""
    w = _UniformWalker(where, config)
    jx = as_open(jaxpr)
    w.run(jx, [True] * len(jx.invars))
    return w.findings


def check_host_transfers(jaxpr, where: str,
                         config: str | None = None) -> list[Finding]:
    """GC002 — no host callbacks/infeed/outfeed inside loop bodies."""
    findings = []
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name in HOST_PRIMS and (
                ".body" in path or "while." in path or "scan." in path):
            findings.append(Finding(
                "GC002", f"{where}{path}/{eqn.primitive.name}",
                f"host transfer '{eqn.primitive.name}' inside a loop body "
                "serialises every iteration on a host round trip",
                config=config))
    return findings


def _avals(jaxpr):
    jx = as_open(jaxpr)
    for v in list(jx.invars) + list(jx.constvars):
        yield v.aval, ""
    for eqn, path in iter_eqns(jx):
        for v in eqn.outvars:
            yield v.aval, f"{path}/{eqn.primitive.name}"


def check_fp64(jaxpr, where: str, config: str | None = None) -> list[Finding]:
    """GC003 — no float64/complex128 anywhere in the graph."""
    findings = []
    seen = set()
    for aval, path in _avals(jaxpr):
        dt = str(getattr(aval, "dtype", ""))
        if dt in _F64_DTYPES and (path or "invars") not in seen:
            seen.add(path or "invars")
            findings.append(Finding(
                "GC003", f"{where}{path or '/invars'}",
                f"{dt} value of shape {tuple(getattr(aval, 'shape', ()))} "
                "in the fit graph (fp64 halves throughput and breaks the "
                "exact-fp32 stop-stat contract)", config=config))
            if len(seen) >= 8:        # one graph full of f64 → don't spam
                break
    return findings


def check_stop_stats_precision(jaxpr, where: str,
                               config: str | None = None) -> list[Finding]:
    """GC004 — scalar stop statistics stay exact fp32: float scalars in
    while carries are f32, scalar psums reduce in f32, and no float
    scalar rides the lossy int8 ring (ppermute)."""
    findings = []
    for eqn, path in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "while":
            body = as_open(eqn.params["body_jaxpr"])
            for i, v in enumerate(body.outvars):
                aval = v.aval
                dt = str(getattr(aval, "dtype", ""))
                if getattr(aval, "shape", None) == () and \
                        "float" in dt and dt != "float32":
                    findings.append(Finding(
                        "GC004", f"{where}{path}/while.carry[{i}]",
                        f"float scalar loop carry is {dt}, not f32 — "
                        "stop statistics must be exact fp32",
                        config=config))
        elif name == "psum":
            for v in eqn.outvars:
                aval = v.aval
                dt = str(getattr(aval, "dtype", ""))
                if getattr(aval, "shape", None) == () and \
                        "float" in dt and dt != "float32":
                    findings.append(Finding(
                        "GC004", f"{where}{path}/psum",
                        f"scalar psum reduces in {dt}, not f32 — stop "
                        "stats must not lose precision on the wire",
                        config=config))
        elif name == "ppermute":
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                dt = str(getattr(aval, "dtype", ""))
                if aval is not None and getattr(aval, "shape", None) == () \
                        and "float" in dt:
                    findings.append(Finding(
                        "GC004", f"{where}{path}/ppermute",
                        "float scalar riding the ppermute ring — scalar "
                        "stop stats must use the exact psum path, not the "
                        "lossy compressed ring", config=config))
    return findings


# ------------------------------------------------- HLO wire-byte account

# Per-device SEND bytes per result byte, ring algorithms (matches
# distribution.compression.ring_wire_bytes and the all-reduce convention
# in launch/hlo_cost's cost model).
def _send_factor(family: str, n: int) -> float:
    if family == "all-reduce":
        return 2.0 * (n - 1) / n            # reduce-scatter + all-gather
    if family == "all-gather":
        return (n - 1) / n                  # result is the full array
    if family == "reduce-scatter":
        return float(n - 1)                 # result is one shard
    if family == "all-to-all":
        return (n - 1) / n
    if family in ("collective-permute", "ragged-all-to-all"):
        return 1.0                          # one hop sends the payload
    return 1.0


def hlo_wire_bytes(hlo: str, axis_size: int) -> dict[str, float]:
    """Per-device wire (send) bytes by collective family from compiled
    HLO text — loop-multiplied via :func:`repro.analysis.hlo_ir.analyze`."""
    from repro.analysis.hlo_ir import analyze
    cost = analyze(hlo)
    return {fam: b * _send_factor(fam, axis_size)
            for fam, b in cost.coll.items()}
