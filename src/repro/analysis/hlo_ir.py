"""Shared HLO-text IR: computations → op lists, with loop-aware costing.

Promoted from ``launch/hlo_cost.py`` (ISSUE 8) so the roofline cost model
and the static-analysis rules parse compiled modules through ONE parser
instead of three private regex copies (``hlo_cost``, ``hlo_analysis`` and
the bench scripts each had their own).  ``launch/hlo_cost.py`` re-exports
everything under its historical names.

The model: ``compiled.as_text()`` is parsed into ``{computation name:
[Op]}``; ``while`` trip counts come from the loop-condition computation
(the compare-against-constant emitted by ``lax.scan`` / ``fori_loop``;
dynamic bounds fall back to 1 and are flagged); :func:`analyze` re-derives
per-chip FLOPs, HBM bytes and collective bytes with loop multiplication.
See the ``launch/hlo_cost.py`` docstring for the costing conventions
(fusion surface traffic, slice-only operands, dot contraction FLOPs).

Parser hardening over the pre-promotion copy (each pinned in
``tests/test_hlo_cost.py``):

  · ``/* ... */`` comments are stripped before parsing — including block
    comments spanning lines (XLA's ``/*index=N*/`` tuple markers were
    already tolerated; a multi-line comment used to desync the
    computation walker);
  · op lines without a leading ``%`` sigil parse (newer XLA dumps print
    some names unsigiled);
  · computation headers without a ``(params) -> result`` signature are
    accepted (``ENTRY main {`` style).
"""
from __future__ import annotations

import dataclasses
import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\(.*->.*)?\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMMENT_RE = re.compile(r"/\*.*?\*/", re.S)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_MEM_OPS = {"fusion", "dot", "convolution", "copy", "gather", "scatter",
            "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
            "transpose", "reshape-and-pad", "pad", "concatenate",
            "select-and-scatter", "reduce-window", "cholesky",
            "triangular-solve"}


def type_numel_bytes(type_str: str) -> tuple[int, int]:
    """(element count, byte size) summed over every shape in ``type_str``
    — tuple types contribute all their members."""
    n_total, b_total = 0, 0
    for dtype, dims in SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        n_total += n
        b_total += n * DTYPE_BYTES[dtype]
    return n_total, b_total


@dataclasses.dataclass
class Op:
    name: str
    rtype: str
    opcode: str
    rest: str        # operand list + attributes (raw tail of the line)


def parse_op_line(line: str) -> Op | None:
    """Parse ``%name = TYPE opcode(rest`` — TYPE may be a tuple type with
    nested parens, layout braces and ``/*index=N*/`` comments; the leading
    ``%`` sigil and a ``ROOT`` marker are optional."""
    s = _COMMENT_RE.sub("", line).strip()
    if s.startswith("ROOT "):
        s = s[5:].lstrip()
    if s.startswith("%"):
        s = s[1:]
    eq = s.find(" = ")
    if eq <= 0:
        return None
    name = s[:eq]
    if not re.fullmatch(r"[\w.\-]+", name):
        return None
    rest = s[eq + 3:]
    if rest.startswith("("):          # tuple type: match parens
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype = rest[:i + 1]
        tail = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    par = tail.find("(")
    if par <= 0:
        return None
    opcode = tail[:par]
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return Op(name, rtype, opcode, tail[par + 1:])


def parse_computations(hlo: str) -> dict[str, list[Op]]:
    """HLO module text → ``{computation name: [Op]}`` (comments stripped,
    block comments may span lines)."""
    comps: dict[str, list[Op]] = {}
    current: list[Op] | None = None
    in_comment = False
    for line in hlo.splitlines():
        if in_comment:
            end = line.find("*/")
            if end < 0:
                continue
            line = line[end + 2:]
            in_comment = False
        line = _COMMENT_RE.sub("", line)
        start = line.find("/*")
        if start >= 0:                # block comment opens, no close here
            line = line[:start]
            in_comment = True
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            current = []
            comps[hdr.group(1)] = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        op = parse_op_line(line)
        if op is not None:
            current.append(op)
    return comps


def trip_count(cond_ops: list[Op]) -> int | None:
    """Largest integer constant in the loop condition ≈ trip count (exact
    for ``lax.scan`` / ``fori_loop``); None when the bound is dynamic."""
    best = None
    for op in cond_ops:
        if op.opcode == "constant":
            m = _CONST_INT_RE.search("constant(" + op.rest)
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
    return best


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    dynamic_loops: int = 0

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {t: v * k for t, v in self.coll.items()},
                    self.dynamic_loops)

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for t, v in o.coll.items():
            self.coll[t] = self.coll.get(t, 0.0) + v
        self.dynamic_loops += o.dynamic_loops


def _dot_flops(op: Op, types: dict[str, str]) -> float:
    out_numel = type_numel_bytes(op.rtype)[0]
    operands = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    contract = 1
    cm = _CONTRACT_RE.search(op.rest)
    if cm and operands:
        lhs_type = types.get(operands[0])
        if lhs_type:
            shapes = SHAPE_RE.findall(lhs_type)
            if shapes:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                for i in (int(x) for x in cm.group(1).split(",") if x):
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_numel * contract


def _fusion_surface_bytes(op: Op, operands: list[str], types: dict,
                          called: list[Op]) -> float:
    """HBM traffic of a fused kernel = its surface, EXCEPT operands the
    fusion only *slices* (scan xs arrays, embedding tables): a parameter
    consumed solely by internal dynamic-slice/gather ops is charged at the
    slice-result size, not the full array."""
    b = float(type_numel_bytes(op.rtype)[1])          # result write
    # called-computation parameter name per position
    param_names: dict[int, str] = {}
    for o in called:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)\)", o.rest)
            if m:
                param_names[int(m.group(1))] = o.name
    # per-param usage inside the fusion
    slice_bytes: dict[str, float] = {}
    only_sliced: dict[str, bool] = {n: True for n in param_names.values()}
    for o in called:
        if o.opcode == "parameter":
            continue
        head = o.rest.split("),")[0]
        used = _OPERAND_RE.findall(head)
        for u in used:
            if u not in only_sliced:
                continue
            if o.opcode in ("dynamic-slice", "gather") and used and used[0] == u:
                slice_bytes[u] = slice_bytes.get(u, 0.0) \
                    + type_numel_bytes(o.rtype)[1]
            else:
                only_sliced[u] = False
    for pos, name in enumerate(operands):
        t = types.get(name)
        if t is None:
            continue
        pname = param_names.get(pos)
        if pname is not None and only_sliced.get(pname) and pname in slice_bytes:
            b += slice_bytes[pname]
        else:
            b += type_numel_bytes(t)[1]
    return b


def analyze(hlo: str, entry: str | None = None) -> Cost:
    """Loop-multiplied per-device cost terms of an HLO module (see the
    module docstring and ``launch/hlo_cost.py`` for conventions)."""
    comps = parse_computations(hlo)
    if not comps:
        return Cost()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break cycles defensively
        ops = comps.get(name, [])
        types = {op.name: op.rtype for op in ops}
        total = Cost()
        for op in ops:
            oc = op.opcode
            if oc == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cm = _COND_ATTR_RE.search(op.rest)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = trip_count(comps.get(cond, [])) if cond else None
                if trips is None:
                    trips, dyn = 1, 1
                else:
                    dyn = 0
                if body:
                    total.add(comp_cost(body).scaled(trips))
                total.dynamic_loops += dyn
                continue
            if oc in ("fusion", "call", "custom-call", "reduce", "sort",
                      "map", "scatter", "select-and-scatter", "reduce-window",
                      "conditional"):
                cm = _CALL_ATTR_RE.search(op.rest)
                if cm and cm.group(1) in comps:
                    inner = comp_cost(cm.group(1))
                    if oc in ("call", "conditional"):
                        total.add(inner)
                    else:
                        # fusion internals: count compute + collectives, but
                        # NOT bytes — the fused kernel's HBM traffic is its
                        # surface (operands + result), added below
                        surf = Cost(flops=inner.flops, bytes=0.0,
                                    coll=dict(inner.coll),
                                    dynamic_loops=inner.dynamic_loops)
                        total.add(surf)
            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                if not oc.endswith("-done"):
                    b = type_numel_bytes(op.rtype)[1]
                    total.coll[base] = total.coll.get(base, 0.0) + b
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, types)
            if oc == "convolution":
                # rough: 2 × out_numel × (kernel numel / out channels)
                total.flops += 2.0 * type_numel_bytes(op.rtype)[0] * 64
            if oc in _MEM_OPS:
                head = op.rest.split("),")[0]
                operands = _OPERAND_RE.findall(head)
                if oc == "fusion":
                    cm2 = _CALL_ATTR_RE.search(op.rest)
                    called = comps.get(cm2.group(1), []) if cm2 else []
                    total.bytes += _fusion_surface_bytes(op, operands, types,
                                                         called)
                    continue
                if oc == "dynamic-update-slice":
                    # in-place (XLA aliases the buffer): traffic = the update
                    # slice read + written, not the whole buffer
                    upd = types.get(operands[1]) if len(operands) > 1 else None
                    b = 2 * type_numel_bytes(upd)[1] if upd else 0
                elif oc in ("dynamic-slice", "gather"):
                    # traffic = the slice/rows actually read + written out,
                    # not the sliced-from operand
                    b = 2 * type_numel_bytes(op.rtype)[1]
                elif oc == "scatter":
                    # traffic ≈ updates read + touched region read/written
                    upd = types.get(operands[-1]) if operands else None
                    b = 3 * type_numel_bytes(upd)[1] if upd else \
                        type_numel_bytes(op.rtype)[1]
                else:
                    b = type_numel_bytes(op.rtype)[1]
                    for operand in operands:
                        t = types.get(operand)
                        if t:
                            b += type_numel_bytes(t)[1]
                total.bytes += b
        memo[name] = total
        return total

    return comp_cost(entry)
