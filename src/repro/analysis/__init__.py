"""Static analysis of the engine's compiled graphs (ISSUE 8).

The repo's worst bugs were *statically detectable graph-contract
violations*: the int8 ring-allreduce deadlock (collectives diverging
across ``while_loop`` trip counts, PR 7), the fp32 J-plateau stop (PR 1),
and kernel-backend config leaking into jit cache reuse (PR 4).  This
package inspects jaxprs and compiled HLO of the engine's fit drivers —
WITHOUT running them — and enforces the distributed-correctness and
performance contracts as named, suppressible rules:

  · :mod:`repro.analysis.hlo_ir`       — the shared HLO text parser
    (promoted from ``launch/hlo_cost.py``; the cost model now imports it)
  · :mod:`repro.analysis.graph_rules`  — jaxpr/HLO passes: collective
    uniformity (GC001), hot-loop hygiene (GC002/GC003/GC004), wire-byte
    cross-check (GC005), recompile sentinel (GC006)
  · :mod:`repro.analysis.ast_rules`    — repo-specific source lint:
    kernel ``mask=`` contract (AST001), hard-coded axis names (AST002),
    Python RNG in traced code (AST003)
  · :mod:`repro.analysis.engine_contracts` — the harness that traces
    ``fit_sharded`` / ``fit_restarts_sharded`` under every
    ``(mode, use_kernel, stats_compression, prefetch)`` combination and
    runs the graph rules over each cell
  · :mod:`repro.analysis.report`       — :class:`Finding` / :class:`Report`
    (rule catalogue, suppression, text/JSON rendering)

CLI: ``python -m repro.launch.lint`` (``--rules``, ``--suppress``,
``--config-matrix``, ``--format {text,json}``; nonzero exit on any
unsuppressed violation) — the ``graph-lint`` CI job runs the full matrix.
"""
from repro.analysis.report import (  # noqa: F401
    Finding, Report, RULE_CATALOGUE, apply_suppressions)
