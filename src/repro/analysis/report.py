"""Findings, the rule catalogue, suppression, and report rendering.

Every rule has a stable id (``GC0xx`` graph-contract, ``AST00x`` source
lint), a kebab-case name, and a severity.  Suppression is explicit and
auditable: ``--suppress GC003`` on the CLI (or ``suppress=`` in the
library API) keeps the finding in the report but marks it
``suppressed: true`` and removes it from the exit-code decision; AST
findings can also be suppressed at the flagged line with a
``# repro-lint: disable=AST002`` comment (same line or the line above).

The JSON schema (``Report.to_json``) is the ``graph-lint`` CI artifact's
contract and is pinned by a golden-file test — bump ``SCHEMA_VERSION``
when it changes shape.
"""
from __future__ import annotations

import dataclasses
import json

SCHEMA_VERSION = 1

# id → (name, severity, one-line description)
RULE_CATALOGUE: dict[str, tuple[str, str, str]] = {
    "GC001": (
        "collective-uniformity", "error",
        "control flow over collectives must be shard-uniform: cond/switch "
        "branches with divergent collective sequences (op, axes, shape, "
        "dtype) need a replicated predicate, and a while_loop issuing "
        "collectives needs an exit predicate derived from collectively-"
        "reduced values — else trip counts diverge across shards and "
        "deadlock (the PR 7 int8-ring class)"),
    "GC002": (
        "host-transfer-in-loop", "error",
        "no host callbacks/infeed/outfeed inside while/scan bodies — a "
        "host round trip per iteration serialises the hot loop"),
    "GC003": (
        "fp64-in-graph", "error",
        "no float64/complex128 values anywhere in a fit graph — fp64 "
        "silently halves throughput and breaks the exact-fp32 stop-stat "
        "contract"),
    "GC004": (
        "stop-stats-precision", "error",
        "scalar stop statistics in the fit loop must be exact fp32: "
        "float scalars in while-loop carries must be f32, scalars must "
        "not ride the lossy int8 ring (ppermute), and scalar psums must "
        "reduce in f32"),
    "GC005": (
        "wire-bytes-mismatch", "error",
        "collective bytes counted in the lowered HLO of one stats "
        "reduction must equal core.engine.stats_wire_bytes's analytic "
        "accounting (the cost model the provisioning planner trusts)"),
    "GC006": (
        "recompile-config", "error",
        "every EngineConfig field must be hashable (static jit cache "
        "key) and sweeping traced arguments (h_star) must not change the "
        "traced graph — a retrace per swept value is a silent compile "
        "storm"),
    "AST001": (
        "kernel-mask-param", "error",
        "public kernel entry points taking the points array must accept "
        "a mask= keyword — the mask operand is how padding, sharding and "
        "minibatch draws compose with every backend"),
    "AST002": (
        "hardcoded-axis-name", "warning",
        "collective calls must take their axis name from config/mesh "
        "arguments, not string literals — literal names hard-couple a "
        "graph to one mesh layout and belong only under the shard_map "
        "facades"),
    "AST003": (
        "python-rng-in-traced", "error",
        "no Python/numpy RNG inside jit-traced or lax-control-flow "
        "functions — host randomness bakes one draw into the compiled "
        "graph as a constant"),
    "AST004": (
        "hardcoded-block-shape", "error",
        "kernel call sites must not hard-code integer block shapes "
        "(block_n/block_q/block_k/block_rows) — blocks resolve through "
        "layout.tile_policy() and the autotune cache; TilePolicy "
        "constructors (the defaults themselves) are exempt"),
}


def rule_name(rule_id: str) -> str:
    return RULE_CATALOGUE[rule_id][0]


def rule_severity(rule_id: str) -> str:
    return RULE_CATALOGUE[rule_id][1]


def normalize_rule_ids(ids) -> set[str]:
    """Accept ids ('GC001') or names ('collective-uniformity'), return ids."""
    by_name = {name: rid for rid, (name, _, _) in RULE_CATALOGUE.items()}
    out = set()
    for raw in ids or ():
        for token in str(raw).split(","):
            token = token.strip()
            if not token:
                continue
            rid = by_name.get(token, token.upper())
            if rid not in RULE_CATALOGUE:
                known = ", ".join(sorted(RULE_CATALOGUE))
                raise ValueError(f"unknown lint rule {token!r} "
                                 f"(known: {known})")
            out.add(rid)
    return out


@dataclasses.dataclass
class Finding:
    rule: str                    # "GC001"
    where: str                   # "fit_sharded/while/body" or "file.py:12"
    message: str
    config: str | None = None    # engine-config cell, e.g. "mode=minibatch|…"
    suppressed: bool = False

    @property
    def name(self) -> str:
        return rule_name(self.rule)

    @property
    def severity(self) -> str:
        return rule_severity(self.rule)

    def as_dict(self) -> dict:
        return {
            "rule": self.rule,
            "name": self.name,
            "severity": self.severity,
            "where": self.where,
            "config": self.config,
            "message": self.message,
            "suppressed": self.suppressed,
        }


def apply_suppressions(findings, suppress) -> list[Finding]:
    """Mark findings whose rule id is in ``suppress`` (ids or names)."""
    ids = normalize_rule_ids(suppress)
    for f in findings:
        if f.rule in ids:
            f.suppressed = True
    return list(findings)


@dataclasses.dataclass
class Report:
    findings: list = dataclasses.field(default_factory=list)
    configs: list = dataclasses.field(default_factory=list)
    rules_run: list = dataclasses.field(default_factory=list)

    def extend(self, findings):
        self.findings.extend(findings)

    def active(self) -> list:
        return [f for f in self.findings if not f.suppressed]

    def errors(self) -> list:
        return [f for f in self.active() if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.active()

    def summary(self) -> dict:
        return {
            "checked_configs": len(self.configs),
            "rules_run": sorted(self.rules_run),
            "findings": len(self.findings),
            "suppressed": sum(f.suppressed for f in self.findings),
            "errors": len(self.errors()),
            "ok": self.ok,
        }

    def to_json(self, indent: int = 2) -> str:
        payload = {
            "schema_version": SCHEMA_VERSION,
            "rules": {
                rid: {"name": name, "severity": sev, "description": desc}
                for rid, (name, sev, desc) in sorted(RULE_CATALOGUE.items())
                if rid in self.rules_run or not self.rules_run
            },
            "configs": list(self.configs),
            "findings": [f.as_dict() for f in self.findings],
            "summary": self.summary(),
        }
        return json.dumps(payload, indent=indent, sort_keys=False)

    def to_text(self) -> str:
        lines = []
        for f in self.findings:
            mark = "suppressed" if f.suppressed else f.severity
            cfg = f" [{f.config}]" if f.config else ""
            lines.append(f"{f.rule} {f.name} ({mark}){cfg} {f.where}: "
                         f"{f.message}")
        s = self.summary()
        lines.append(
            f"graph-lint: {s['checked_configs']} config(s), "
            f"{s['findings']} finding(s) "
            f"({s['suppressed']} suppressed, {s['errors']} error(s)) — "
            + ("OK" if self.ok else "FAIL"))
        return "\n".join(lines)
