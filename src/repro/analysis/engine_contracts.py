"""The config-matrix harness: trace every engine cell, run the rules.

This is the linter's driver.  For every ``(mode, use_kernel,
stats_compression, prefetch)`` combination × algorithm it builds the
*production* shard_map'd fit programs through
``ClusteringEngine.sharded_fit_callable`` / ``sharded_restarts_callable``
(the same code path ``fit_sharded`` runs), traces them with
``jax.make_jaxpr`` — tracing never executes the fit — and walks the
jaxprs with the :mod:`repro.analysis.graph_rules` passes (GC001–GC004).
Two checks need more than a trace:

  GC005  lowers + compiles ONE stats reduction (``_stats_reducer``'s
         ``reduce_stats`` under shard_map — a sub-second compile, no
         fit execution) and cross-checks the collective bytes in the
         optimized HLO against ``stats_wire_bytes``'s analytic account;
  GC006  hashes every ``EngineConfig`` field (static jit cache key) and
         traces the fit at two ``h_star`` values — identical jaxprs
         prove the sweep axis is traced, not baked in.

Params come from ``jax.eval_shape`` over the real initialisers, so even
k-means++ init never runs — the whole lint is trace/compile only.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp

from repro.analysis import graph_rules
from repro.analysis.report import Finding, Report

GRAPH_RULES = ("GC001", "GC002", "GC003", "GC004", "GC005", "GC006")
ALGORITHMS = ("kmeans", "em")

_N_POINTS, _DIM, _K = 64, 3, 3


def _data(n_points: int = _N_POINTS, dim: int = _DIM):
    # deterministic, RNG-free: the lint only reads shapes and structure
    return (jnp.arange(n_points * dim, dtype=jnp.float32)
            .reshape(n_points, dim) % 17.0)


def default_mesh():
    import repro.compat  # noqa: F401  (jax.make_mesh on older jax)
    return jax.make_mesh((len(jax.devices()),), ("data",))


def config_matrix(matrix: str = "full"):
    """Every fit-relevant static-config combination (16 cells), or the
    4-cell ``quick`` diagonal that still covers each option at least
    once."""
    from repro.core.engine import EngineConfig
    cells = []
    for mode, kern, comp, pref in itertools.product(
            ("full", "minibatch"), (False, True),
            ("none", "int8_ef"), (False, True)):
        cells.append(EngineConfig(
            max_iters=4, chunks=4, mode=mode,
            batch_chunks=2 if mode == "minibatch" else 0,
            use_kernel=kern, stats_compression=comp, prefetch=pref))
    if matrix == "quick":
        picks = {("full", False, "none", False),
                 ("full", True, "int8_ef", True),
                 ("minibatch", True, "none", True),
                 ("minibatch", False, "int8_ef", False)}
        cells = [c for c in cells
                 if (c.mode, c.use_kernel, c.stats_compression,
                     c.prefetch) in picks]
    return cells


def cell_desc(alg: str, cfg) -> str:
    return (f"{alg}|mode={cfg.mode}|kernel={int(cfg.use_kernel)}"
            f"|comp={cfg.stats_compression}|prefetch={int(cfg.prefetch)}")


def _zero_params(eng, x, k: int, restarts: int | None = None):
    """Concrete zero-filled params with the initialiser's exact pytree
    structure — via eval_shape, so init itself never executes."""
    key = jax.random.key(0)
    if restarts is None:
        shapes = jax.eval_shape(lambda kk: eng.init(kk, x, k), key)
    else:
        shapes = jax.eval_shape(
            lambda kk: eng.init_restarts(kk, x, k, restarts), key)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


_JAXPR_CHECKS = {
    "GC001": graph_rules.check_collective_uniformity,
    "GC002": graph_rules.check_host_transfers,
    "GC003": graph_rules.check_fp64,
    "GC004": graph_rules.check_stop_stats_precision,
}


def check_cell(alg: str, cfg, mesh, rules, *,
               include_restarts: bool = True) -> list[Finding]:
    """Trace one engine cell's fit (and restarts) drivers, run the
    jaxpr rules."""
    from repro.core.engine import ClusteringEngine
    desc = cell_desc(alg, cfg)
    eng = ClusteringEngine(alg, cfg)
    x = _data()
    findings: list[Finding] = []
    progs = [("fit_sharded",
              eng.sharded_fit_callable(x, _zero_params(eng, x, _K), mesh))]
    if include_restarts:
        progs.append((
            "fit_restarts_sharded",
            eng.sharded_restarts_callable(
                x, _zero_params(eng, x, _K, restarts=2), mesh)))
    for name, prog in progs:
        jaxpr = jax.make_jaxpr(prog.fn)(*prog.args)
        for rule in rules:
            check = _JAXPR_CHECKS.get(rule)
            if check is not None:
                findings += check(jaxpr, name, config=desc)
    return findings


# ------------------------------------------------------------------ GC005

def check_wire_bytes(mesh, algorithms=ALGORITHMS,
                     compressions=("none", "int8_ef"),
                     analytic_fn=None) -> list[Finding]:
    """GC005 — compile one stats reduction per (algorithm, compression),
    count its HLO collective bytes, compare with the analytic account.

    ``analytic_fn(stats_like, axis_size, compression)`` defaults to
    ``core.engine.stats_wire_bytes`` (injectable so the mismatch path is
    testable)."""
    import math

    from jax.sharding import PartitionSpec as P
    from repro.core.engine import (ClusteringEngine, EngineConfig,
                                   _stats_reducer, stats_wire_bytes)
    from repro.distribution.compression import ring_wire_bytes
    analytic_fn = analytic_fn or stats_wire_bytes
    n = mesh.devices.size
    findings = []
    # probe shapes are larger than the trace matrix's (and axis-aligned)
    # so the real byte counts dwarf the ring-padding slack below
    probe_k, probe_dim = max(8, n), 32
    for alg_name, comp in itertools.product(algorithms, compressions):
        cfg = EngineConfig(stats_compression=comp, axis_name="data",
                           stats_axis_size=n if comp != "none" else 0)
        eng = ClusteringEngine(alg_name, cfg)
        x = _data(dim=probe_dim)
        params = _zero_params(eng, x, probe_k)
        stats = eng.algorithm.zero_stats(params)
        init_ef, reduce_stats = _stats_reducer(eng.algorithm, cfg)

        def one_reduction(stats, params):
            out, _ = reduce_stats(stats, init_ef(stats), params)
            return out

        rep_s = jax.tree.map(lambda a: P(*(None,) * jnp.ndim(a)), stats)
        rep_p = jax.tree.map(lambda a: P(*(None,) * jnp.ndim(a)), params)
        fn = jax.shard_map(one_reduction, mesh=mesh,
                           in_specs=(rep_s, rep_p), out_specs=rep_s,
                           check_vma=False)
        hlo = jax.jit(fn).lower(stats, params).compile().as_text()
        hlo_per_family = graph_rules.hlo_wire_bytes(hlo, n)
        measured = sum(hlo_per_family.values())
        expected = analytic_fn(stats, n, comp)
        # principled slack: the int8 ring pads each leaf's per-hop chunk
        # to ceil(numel/N), and XLA may leave the shared-scale pmax
        # unmerged with the reduction — both bounded per leaf; an
        # account/dtype error produces a ~4× mismatch, far outside it
        slack = 64.0 + 0.02 * expected
        if comp == "int8_ef":
            for a in jax.tree.leaves(stats):
                numel = math.prod(jnp.shape(a))
                if jnp.ndim(a) >= 1:
                    slack += (2 * (n - 1) * math.ceil(numel / n)
                              - ring_wire_bytes(numel, n))
                    slack += ring_wire_bytes(4, n)
        if abs(measured - expected) > slack:
            fam = ", ".join(f"{k}={v:.0f}"
                            for k, v in sorted(hlo_per_family.items()))
            findings.append(Finding(
                "GC005", f"stats_reduction[{alg_name}]",
                f"compiled HLO moves {measured:.0f} wire bytes/device "
                f"({fam}) but stats_wire_bytes accounts {expected} "
                f"(tolerance {slack:.0f}) — the analytic cost model has "
                "drifted from the compiled graph",
                config=f"{alg_name}|comp={comp}"))
    return findings


# ------------------------------------------------------------------ GC006

def check_config_static(cfg=None) -> list[Finding]:
    """GC006 (static half) — every EngineConfig field must hash: the
    config is a static jit argument, and one unhashable field turns every
    fit call into a TypeError (or, with a custom __hash__ that skips the
    field, into silent cache collisions)."""
    from repro.core.engine import EngineConfig
    cfg = cfg if cfg is not None else EngineConfig()
    findings = []
    for field in dataclasses.fields(cfg):
        try:
            hash(getattr(cfg, field.name))
        except TypeError:
            findings.append(Finding(
                "GC006", f"EngineConfig.{field.name}",
                f"field value {getattr(cfg, field.name)!r} is unhashable "
                "— EngineConfig is a static jit argument and every field "
                "must be part of the cache key"))
    try:
        hash(cfg)
    except TypeError:
        findings.append(Finding(
            "GC006", "EngineConfig",
            "config instance is unhashable — cannot be a static jit "
            "argument"))
    return findings


def check_h_star_traced(mesh, alg: str = "kmeans") -> list[Finding]:
    """GC006 (sweep half) — tracing the fit at two h* values must yield
    the *identical* jaxpr: h* is the paper's sweep axis, and a config
    that bakes it into the graph recompiles once per swept value."""
    from repro.core.engine import ClusteringEngine, EngineConfig
    eng = ClusteringEngine(alg, EngineConfig(max_iters=4, chunks=4))
    x = _data()
    p0 = _zero_params(eng, x, _K)
    texts = []
    for hs in (0.01, 0.02):
        prog = eng.sharded_fit_callable(x, p0, mesh, h_star=hs)
        texts.append(str(jax.make_jaxpr(prog.fn)(*prog.args)))
    if texts[0] != texts[1]:
        return [Finding(
            "GC006", "fit_sharded(h_star)",
            "sweeping h_star changes the traced graph — the stopping "
            "threshold is baked in as a constant instead of riding as a "
            "traced argument, so every swept value pays a full "
            "recompile", config=f"{alg}")]
    return []


# ------------------------------------------------------------------ driver

def run_graph_lint(mesh=None, matrix: str = "full", rules=None,
                   algorithms=ALGORITHMS, *,
                   include_restarts: bool = True) -> Report:
    """Trace the full engine config matrix and run every requested
    graph-contract rule; returns the populated :class:`Report`."""
    mesh = mesh if mesh is not None else default_mesh()
    rules = tuple(rules) if rules else GRAPH_RULES
    report = Report(rules_run=[r for r in GRAPH_RULES if r in rules])
    if any(r in _JAXPR_CHECKS for r in rules):
        for cfg in config_matrix(matrix):
            for alg in algorithms:
                report.configs.append(cell_desc(alg, cfg))
                report.extend(check_cell(alg, cfg, mesh, rules,
                                         include_restarts=include_restarts))
    if "GC005" in rules:
        report.extend(check_wire_bytes(mesh, algorithms))
    if "GC006" in rules:
        report.extend(check_config_static())
        report.extend(check_h_star_traced(mesh))
    return report
