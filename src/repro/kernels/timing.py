"""One timing methodology for every committed number (ISSUE 9).

``benchmarks/run.py``, ``benchmarks/sharded_overlap_worker.py`` and the
kernel autotuner used to carry their own warm-then-loop timing snippets;
this module is the single copy.  The contract:

  · **warmup** calls first (default 1) — the jit compile and any lazy
    initialisation happen outside the timed region;
  · every timed call is bracketed by ``jax.block_until_ready`` on its
    result, so async dispatch never hides device time;
  · **reps** samples reduced to one number — ``"median"`` by default
    (robust to one-off scheduler hiccups), ``"min"`` for the
    CPU-substrate benches where host scheduling noise dominates and the
    floor is the signal, ``"mean"`` when you want the average.

``timer`` is injectable (defaults to ``time.perf_counter``) so tests can
drive winner selection with a deterministic fake clock.
"""
from __future__ import annotations

import statistics
import time

import jax

REDUCERS = {
    "median": statistics.median,
    "min": min,
    "mean": statistics.fmean,
}


def time_callable(fn, *args, reps: int = 5, warmup: int = 1,
                  reduce: str = "median", timer=None) -> float:
    """Seconds per call of ``fn(*args)`` under the shared methodology.

    Runs ``warmup`` untimed calls, then ``reps`` timed calls — each one
    ``jax.block_until_ready``-bracketed — and reduces the samples with
    ``reduce`` ("median" | "min" | "mean").
    """
    if reduce not in REDUCERS:
        raise ValueError(f"unknown reduce {reduce!r}; choose one of "
                         f"{sorted(REDUCERS)}")
    if reps < 1:
        raise ValueError(f"reps must be >= 1; got {reps}")
    clock = time.perf_counter if timer is None else timer
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(reps):
        t0 = clock()
        jax.block_until_ready(fn(*args))
        samples.append(clock() - t0)
    return float(REDUCERS[reduce](samples))
