"""Shared layout helpers for the kernel packages (ISSUE 4).

Every kernel wrapper used to carry its own ``_round_up`` / pad / chunk
plumbing; this module is the single copy.  It owns:

  · alignment arithmetic (:func:`round_up`) and the per-backend
    :class:`TilePolicy` table — TPU pads features to the 128-lane vector
    width and clusters to 8 sublanes; the GPU (Triton) policy uses the
    16-aligned shapes tensor-core ``dot`` wants and a smaller row block;
    ``interpret`` mirrors the TPU policy so CPU CI exercises TPU shapes.

  · the two chunk layouts the engine and the ops share:
    :func:`chunk_bounds` (static remainder-absorbing [start, stop) slices
    over a flat N — the kernels' streaming entry points) and
    :func:`chunk_points` (the engine's padded ``[C, ceil(N/C), D]`` + mask
    reshape).  ``kernels.kmeans_assign.ops.chunk_bounds`` and
    ``core.kmeans.chunk_points`` re-export these names, so historical
    import sites keep working.

  · the shared chunked-call drivers: :func:`chunked_sweep` streams a flat
    array through statically-sliced op calls, and
    :func:`subsampled_stats` runs a gather-free pass over a drawn subset
    of the ``chunk_points`` layout (``lax.dynamic_index_in_dim`` per scan
    step — each op call sees one statically-shaped ``[P, D]`` chunk, and
    the ``[B, P, D]`` gathered copy never materialises).  This is what
    lets ``mode="minibatch"`` compose with ``use_kernel=True``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# --------------------------------------------------------------------------
# Per-backend tile / padding policy
# --------------------------------------------------------------------------

def next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class TilePolicy:
    """Row-block and padding alignment for one kernel backend.

    ``pow2`` forces every padded block dimension (and the row block) up to
    the next power of two — Triton requires pow2 block shapes, while the
    TPU lowering only needs sublane/lane multiples.
    """
    block_rows: int      # default rows per grid step
    row_align: int       # rows are padded to a multiple of the block
    k_align: int         # cluster/component axis padding multiple
    d_align: int         # feature axis padding multiple
    pow2: bool = False   # padded dims must be powers of two (Triton)

    def _aligned(self, x: int, m: int) -> int:
        r = round_up(x, m)
        return next_pow2(r) if self.pow2 else r

    def block_for(self, n: int, block_rows: int | None = None) -> int:
        # explicit overrides are aligned too, so a hand-picked block_n can
        # never violate the backend's (e.g. Triton pow2) block-shape rules
        b = self._aligned(self.block_rows if block_rows is None
                          else block_rows, self.row_align)
        return min(b, self._aligned(max(n, self.row_align), self.row_align))

    def align_k(self, k: int) -> int:
        return self._aligned(k, self.k_align)

    def align_d(self, d: int) -> int:
        return self._aligned(d, self.d_align)


_TPU_POLICY = TilePolicy(block_rows=1024, row_align=8, k_align=8, d_align=128)

TILE_POLICIES: dict[str, TilePolicy] = {
    "tpu": _TPU_POLICY,
    # interpret emulates the TPU lowering — same shapes, so CPU CI parity
    # tests cover the tiles the TPU path compiles
    "interpret": _TPU_POLICY,
    # Triton tensor-core dot wants every dim >= 16 and pow2 block shapes;
    # the smaller row block keeps one (block, D) tile within shared memory
    "gpu": TilePolicy(block_rows=256, row_align=16, k_align=16, d_align=32,
                      pow2=True),
}


def tile_policy(backend: str) -> TilePolicy:
    return TILE_POLICIES.get(backend, _TPU_POLICY)


# --------------------------------------------------------------------------
# Batch buckets (the serving layer's fixed compile shapes)
# --------------------------------------------------------------------------

DEFAULT_BUCKETS = (256, 1024, 4096, 16384)


def bucket_for(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest padded size in the bucket ladder that holds ``n`` rows.

    The serving layer pads every drained request batch up to a bucket so
    XLA sees a closed set of shapes — one compiled program per (model,
    bucket) instead of one per arriving batch size.

    Contract (ISSUE 9): within the ladder, the smallest bucket ≥ n wins;
    **above the largest bucket the ladder continues in multiples of that
    bucket** (⌈n/B⌉·B for B = ``buckets[-1]``), so the shape set stays
    closed and countable at any n instead of failing implicitly.  Callers
    that must bound admitted sizes (the serving queue) enforce their own
    cap *before* bucketing — ``ClusterServer`` rejects oversize batches at
    admission.  Padding that is impossible fails loud: ``n < 1`` (nothing
    to pad) or an empty ``buckets`` ladder raise ``ValueError``.
    """
    if not buckets:
        raise ValueError("bucket_for needs a non-empty bucket ladder — "
                         "padding to a bucket is impossible without one")
    if n < 1:
        raise ValueError(f"cannot pad a batch of {n} rows to a bucket — "
                         "batches must have at least one row")
    for b in buckets:
        if n <= b:
            return b
    return round_up(n, buckets[-1])


def pad_to_bucket(x, bucket: int):
    """[N, D] → ([bucket, D], mask [bucket]) zero-padded; mask 0 marks the
    padding rows the ops' mask operand drops from labels and statistics."""
    n = x.shape[0]
    xp = jnp.pad(jnp.asarray(x, jnp.float32), ((0, bucket - n), (0, 0)))
    return xp, (jnp.arange(bucket) < n).astype(jnp.float32)


# --------------------------------------------------------------------------
# Chunk layouts
# --------------------------------------------------------------------------

def chunk_bounds(n: int, chunks: int) -> list[tuple[int, int]]:
    """Static [start, stop) slices covering N in ``chunks`` pieces; the last
    piece absorbs the remainder when chunks does not divide N."""
    c = max(1, min(int(chunks), n))
    per = -(-n // c)
    return [(s, min(s + per, n)) for s in range(0, n, per)]


def chunk_points(x, chunks: int):
    """[N, D] → ([C, ceil(N/C), D], mask [C, ceil(N/C)]) with zero-padding.

    Row-major: global row i lives at chunk i // per, slot i % per.  The mask
    is 1.0 for real rows, 0.0 for padding.
    """
    n, d = x.shape
    c = max(1, min(int(chunks), n))
    per = -(-n // c)
    pad = c * per - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    mask = (jnp.arange(c * per) < n).astype(jnp.float32).reshape(c, per)
    return xp.reshape(c, per, d), mask


# --------------------------------------------------------------------------
# Shared chunked-call drivers
# --------------------------------------------------------------------------

def chunked_sweep(call, n: int, chunks: int):
    """Stream a flat N through statically-sliced op calls.

    ``call(lo, hi)`` runs the op on rows [lo, hi) and returns
    ``(rows, *additive)`` — a per-row output (concatenated across chunks)
    plus additive sufficient statistics (summed).  Returns the same tuple
    shape the monolithic call produces.
    """
    rows, adds = [], None
    for a, b in chunk_bounds(n, chunks):
        r, *st = call(a, b)
        rows.append(r)
        adds = st if adds is None else [x + y for x, y in zip(adds, st)]
    # rows concatenate along the row axis (last — batched labels are [R, N])
    return (jnp.concatenate(rows, axis=-1), *adds)


def subsampled_stats(call, zero, xc, mask, idx, prefetch: bool = False):
    """Gather-free stats over drawn chunks of a ``chunk_points`` layout.

    ``call(x_chunk [P, D], w [P])`` returns a pytree of additive statistics
    (zero-initialised from the matching ``zero`` tree); ``idx`` is a traced
    [B] vector of chunk indices.  Each scan step ``dynamic_index``es one
    statically-shaped chunk out of ``xc [C, P, D]`` — no ``[B, P, D]``
    gathered copy ever materialises — and accumulates.  Returns
    ``(stats, n_batch)`` with ``n_batch`` the summed mask weight of the
    drawn rows.  Composes with ``vmap``: per-restart draws batch the
    indexed chunk, which the ops' batching rules route onto the kernels'
    restart grid axis.

    ``prefetch=True`` double-buffers the scan: the carry holds the chunk
    being processed while the body issues the load of the *next* drawn
    chunk, which has no data dependency on the current ``call`` — the
    scheduler can overlap copy i+1 with compute i.  Same chunk order, same
    adds: results are bit-identical.
    """
    def load(i):
        xi = jax.lax.dynamic_index_in_dim(xc, i, 0, keepdims=False)
        mi = jax.lax.dynamic_index_in_dim(mask, i, 0, keepdims=False)
        return xi, mi

    init = (zero, jnp.zeros((), jnp.float32))
    if prefetch and idx.shape[0] > 1:
        # shift the draw order one step: step t computes on the chunk
        # loaded at t-1 and loads the chunk for t+1 (the last step's load
        # is a harmless repeat that nothing computes on)
        nxt = jnp.concatenate([idx[1:], idx[-1:]])

        def body(carry, i_nxt):
            (acc, nb), (xi, mi) = carry
            x_nxt, m_nxt = load(i_nxt)
            st = call(xi, mi)
            out = (jax.tree.map(jnp.add, acc, st), nb + jnp.sum(mi))
            return (out, (x_nxt, m_nxt)), None

        ((stats, n_batch), _), _ = jax.lax.scan(
            body, (init, load(idx[0])), nxt)
    else:
        def body(carry, i):
            acc, nb = carry
            xi, mi = load(i)
            st = call(xi, mi)
            return (jax.tree.map(jnp.add, acc, st), nb + jnp.sum(mi)), None

        (stats, n_batch), _ = jax.lax.scan(body, init, idx)
    return stats, n_batch
