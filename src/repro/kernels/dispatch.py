"""Backend-dispatching layer for the kernel packages (ISSUE 4).

One registry replaces the old two-state world ("TPU-compiled or
CPU-interpret") that each ``ops.py`` re-implemented privately.  An op is a
named :class:`KernelOp` with one implementation per backend:

  · ``tpu``       — Pallas, compiled for the TPU (Mosaic lowering)
  · ``gpu``       — Pallas, Triton lowering with the GPU tile policy
  · ``interpret`` — the same Pallas kernel run by the interpreter (any
                    host; this is what CPU CI exercises)
  · ``xla``       — the pure-jnp reference contract (always available;
                    also the numerically-independent parity oracle)

Resolution order for a call: an explicit ``backend=`` argument → the
process-wide :func:`force_backend` override → the default mapping from
``jax.default_backend()`` (tpu → ``tpu``, gpu → ``gpu``, anything else →
``interpret``).  Resolution happens *before* any jit boundary, so the
chosen backend is a static argument and switching backends never reuses a
stale trace.

Tests (and downstream tooling) can force any path per op with
:func:`register_backend` / :func:`force_backend` — that is how the parity
goldens pin kernel-vs-reference on every backend available in CI.
"""
from __future__ import annotations

import contextlib
import functools
import threading

import jax
import jax.numpy as jnp

PALLAS_BACKENDS = ("tpu", "gpu", "interpret")
KNOWN_BACKENDS = PALLAS_BACKENDS + ("xla",)

_STATE = threading.local()


def default_backend() -> str:
    """Map ``jax.default_backend()`` onto a registry backend name."""
    forced = getattr(_STATE, "forced", None)
    if forced is not None:
        return forced
    jb = jax.default_backend()
    if jb in ("tpu", "gpu"):
        return jb
    return "interpret"


def resolve_backend(backend: str | None = None,
                    interpret: bool | None = None) -> str:
    """Normalise the public ops' ``backend=`` / legacy ``interpret=`` args.

    ``interpret=True`` is the historical way to force the interpreter;
    ``interpret=False`` forces the compiled Pallas path for the current
    platform.  ``backend`` (a registry name) wins when both are given.
    """
    if backend is not None:
        if backend == "auto":
            return default_backend()
        # custom names registered via register_backend are legal; a name no
        # op knows fails at the per-op lookup with the available list
        return backend
    if interpret is True:
        return "interpret"
    if interpret is False:
        jb = jax.default_backend()
        if jb in ("tpu", "gpu"):
            return jb
        raise ValueError(
            "interpret=False requests the compiled Pallas path, but "
            f"jax.default_backend()={jb!r} has no Pallas lowering here; "
            "pass backend='interpret' / 'xla' instead")
    return default_backend()


@contextlib.contextmanager
def force_backend(name: str):
    """Force every dispatched op onto ``name`` within the context (tests).

    Custom names installed via :func:`register_backend` are legal; forcing
    a name an op has not registered fails at that op's lookup with the
    available list.
    """
    prev = getattr(_STATE, "forced", None)
    _STATE.forced = name
    try:
        yield
    finally:
        _STATE.forced = prev


class KernelOp:
    """A named op with per-backend implementations.

    Implementations share one internal contract per op (the op's ``ops.py``
    documents it); ``__call__`` resolves the backend name and forwards.
    """

    def __init__(self, name: str):
        self.name = name
        self._impls: dict[str, object] = {}

    def register(self, backend: str):
        def deco(fn):
            self._impls[backend] = fn
            return fn
        return deco

    def backends(self) -> tuple[str, ...]:
        return tuple(sorted(self._impls))

    def impl(self, backend: str | None = None, interpret: bool | None = None):
        """Resolve → (backend_name, implementation)."""
        b = resolve_backend(backend, interpret)
        if b not in self._impls:
            raise NotImplementedError(
                f"kernel op {self.name!r} has no {b!r} backend registered "
                f"(available: {self.backends()}); register one with "
                f"repro.kernels.dispatch.register_backend({self.name!r}, "
                f"{b!r}, fn)")
        return b, self._impls[b]

    def __call__(self, *args, backend: str | None = None,
                 interpret: bool | None = None, **kw):
        _, fn = self.impl(backend, interpret)
        return fn(*args, **kw)


_OPS: dict[str, KernelOp] = {}


def get_op(name: str) -> KernelOp:
    op = _OPS.get(name)
    if op is None:
        op = _OPS[name] = KernelOp(name)
    return op


def register_backend(op_name: str, backend: str, fn=None):
    """Register (or override) ``fn`` as ``op_name``'s ``backend`` impl.

    Usable as a direct call or as a decorator::

        @register_backend("kmeans_assign", "mybackend")
        def my_impl(x, w, c, *, block_n): ...

    Tests use this hook to force any path (including fakes) through the
    public ops without monkeypatching module internals.
    """
    op = get_op(op_name)
    if fn is None:
        return op.register(backend)
    op.register(backend)(fn)
    return fn


def registered_ops() -> dict[str, tuple[str, ...]]:
    """{op name: registered backends} — the README support matrix's source."""
    return {name: op.backends() for name, op in sorted(_OPS.items())}


def make_dispatched_factory(op: KernelOp, n_out: int):
    """The restart-axis ``custom_vmap`` scaffolding, shared by the
    clustering ops (one copy of the broadcast rule — it must not drift
    between kmeans_assign and gmm_estep).

    Returns an lru-cached factory ``(block_n, backend) → callable`` where
    the callable takes ``(x, w, *params)`` arrays and re-resolves the
    registry impl on every call (so ``register_backend`` overrides
    installed later still win).  The vmap rule maps a batched call onto
    the kernels' leading restart axis: batched operands arrive with the
    batch axis at 0; unbatched params (and ``w`` when only the points are
    batched) are broadcast so the impl sees one consistent [R, ...]
    contract.
    """

    @functools.lru_cache(maxsize=None)
    def factory(block_n: int, backend: str):
        def call(x, w, *params):
            _, fn = op.impl(backend)
            return fn(x, w, *params, block_n=block_n)

        cv = jax.custom_batching.custom_vmap(call)

        @cv.def_vmap
        def _rule(axis_size, in_batched, x, w, *params):
            params = tuple(
                p if batched else jnp.broadcast_to(p,
                                                   (axis_size,) + p.shape)
                for p, batched in zip(params, in_batched[2:]))
            if x.ndim == 3 and w.ndim == 1:
                w = jnp.broadcast_to(w, (axis_size,) + w.shape)
            return call(x, w, *params), (True,) * n_out

        return cv

    return factory
