"""Pure-jnp oracle for the fused k-means assignment kernel.

``kmeans_assign_masked_ref`` is the one copy of the reference math — the
registered ``xla`` backend delegates here (so the test oracle and the
backend users run with ``kernel_backend="xla"`` cannot drift), and the
historical ``kmeans_assign_ref`` signature wraps it with unit weights.
"""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_masked_ref(x, w, centroids):
    """(labels [N] i32, sums [K,D] f32, counts [K] f32, j [] f32).

    ``w`` are f32 row weights; weight-0 rows are labelled -1 and carry no
    statistics — the kernel ops' mask contract.
    """
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    w = w.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    mind2 = jnp.maximum(jnp.min(d2, axis=-1), 0.0)
    j = jnp.sum(mind2 * w)
    k = c.shape[0]
    sums = jnp.zeros_like(c).at[labels].add(x * w[:, None])
    counts = jnp.zeros((k,), jnp.float32).at[labels].add(w)
    return jnp.where(w > 0, labels, -1), sums, counts, j


def kmeans_assign_ref(x, centroids):
    """(labels [N] i32, sums [K,D] f32, counts [K] f32, j [1] f32)."""
    labels, sums, counts, j = kmeans_assign_masked_ref(
        x, jnp.ones((x.shape[0],), jnp.float32), centroids)
    return labels, sums, counts, j[None]
