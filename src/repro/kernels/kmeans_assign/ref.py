"""Pure-jnp oracle for the fused k-means assignment kernel."""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_ref(x, centroids):
    """(labels [N] i32, sums [K,D] f32, counts [K] f32, j [1] f32)."""
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    j = jnp.sum(jnp.maximum(jnp.min(d2, axis=-1), 0.0))[None]
    k = c.shape[0]
    sums = jnp.zeros_like(c).at[labels].add(x)
    counts = jnp.zeros((k,), jnp.float32).at[labels].add(1.0)
    return labels, sums, counts, j
