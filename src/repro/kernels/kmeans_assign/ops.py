"""Public k-means assignment op, dispatched through the backend registry.

``kernels.dispatch`` selects the implementation per call: ``tpu`` /
``gpu`` compile the Pallas kernel (Mosaic / Triton lowering, per-backend
``layout.TilePolicy`` padding), ``interpret`` runs the same kernel under
the Pallas interpreter (the CPU CI path), and ``xla`` is the pure-jnp
reference contract.  ``backend=None`` auto-resolves from
``jax.default_backend()``; the legacy ``interpret=`` kwarg still forces
the interpreter.

Padding policy (Pallas backends):
  D → multiple of the backend's lane alignment with zeros — distances
      unchanged;
  K → multiple of the sublane alignment with +1e9 sentinel centroids —
      never argmin;
  N → multiple of block_n — padded rows carry weight 0.

Restart axis: ``centroids`` (and optionally ``x``/``mask``) accept a
leading [R, ...] batch dimension, mapped onto the kernel grid's restart
axis; a ``jax.custom_batching.custom_vmap`` rule routes ``jax.vmap`` of
this op (the engine's multi-restart driver) onto that axis instead of
failing in the pallas batching rule.

``mask`` is an optional [N] f32 row-weight vector (0 drops a row from the
statistics and labels it -1) — the contract the engine's padded chunk
layout and minibatch draws rely on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune, dispatch, layout
from repro.kernels.layout import chunk_bounds  # noqa: F401  (historical home)

from .kernel import kmeans_assign_kernel

_PAD_CENTROID = 1.0e9

OP = dispatch.get_op("kmeans_assign")


# --------------------------------------------------------------------------
# Backend implementations.  Shared internal contract:
#   impl(x, w, c, *, block_n) -> (labels, sums, counts, j)
# with x [N, D] | [R, N, D], w [N] | [R, N], c [K, D] | [R, K, D]; outputs
# carry the leading R iff the centroids do.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_n", "backend"))
def _pallas_impl(x, w, c, *, block_n: int, backend: str):
    pol = layout.tile_policy(backend)
    batched = c.ndim == 3
    c3 = c if batched else c[None]
    x3 = x if x.ndim == 3 else x[None]
    w2 = w if w.ndim == 2 else w[None]
    if c3.ndim != 3 or x3.ndim != 3:
        raise NotImplementedError(
            "kmeans_assign supports one leading restart axis at most; "
            f"got x {x.shape}, centroids {c.shape}")
    n, d = x3.shape[1:]
    k = c3.shape[1]
    n_pad = layout.round_up(n, block_n)
    d_pad = pol.align_d(d)
    k_pad = pol.align_k(k)
    xp = jnp.pad(x3.astype(jnp.float32),
                 ((0, 0), (0, n_pad - n), (0, d_pad - d)))
    wp = jnp.pad(w2.astype(jnp.float32), ((0, 0), (0, n_pad - n)))
    cp = jnp.pad(c3.astype(jnp.float32),
                 ((0, 0), (0, k_pad - k), (0, d_pad - d)))
    if k_pad > k:  # sentinel rows: huge distance, never selected
        cp = cp.at[:, k:, :].set(_PAD_CENTROID)
    if backend == "gpu":   # parallel grid cells: split reduction
        labels, sums, counts, j = kmeans_assign_kernel(
            xp, wp, cp, block_n=block_n, interpret=False, accumulate=False)
        sums, counts, j = (jnp.sum(sums, axis=1), jnp.sum(counts, axis=1),
                           jnp.sum(j, axis=1))
    else:
        labels, sums, counts, j = kmeans_assign_kernel(
            xp, wp, cp, block_n=block_n,
            interpret=(backend == "interpret"))
    labels, sums = labels[:, :n], sums[:, :k, :d]
    counts, j = counts[:, :k], j[:, 0]
    if not batched:
        labels, sums, counts, j = labels[0], sums[0], counts[0], j[0]
    return labels, sums, counts, j


for _b in dispatch.PALLAS_BACKENDS:
    OP.register(_b)(functools.partial(_pallas_impl, backend=_b))


@OP.register("xla")
@functools.partial(jax.jit, static_argnames=("block_n",))
def _xla_impl(x, w, c, *, block_n: int):
    # delegates to the ref oracle (one copy of the math — see ref.py)
    del block_n
    from .ref import kmeans_assign_masked_ref
    if c.ndim == 2:
        return kmeans_assign_masked_ref(x, w, c)
    return jax.vmap(kmeans_assign_masked_ref,
                    in_axes=(0 if x.ndim == 3 else None,
                             0 if w.ndim == 2 else None, 0))(x, w, c)


# --------------------------------------------------------------------------
# Public op (+ the custom_vmap restart-axis rule)
# --------------------------------------------------------------------------

# (block_n, backend) → custom_vmap-wrapped call; the restart-axis batching
# rule lives in dispatch.make_dispatched_factory (shared with gmm_estep)
_dispatched = dispatch.make_dispatched_factory(OP, n_out=4)


def kmeans_assign(x, centroids, *, mask=None, block_n: int | None = None,
                  backend: str | None = None, interpret: bool | None = None):
    """Fused assignment: (labels [N] i32, sums [K,D], counts [K], j []).

    Accepts a leading restart axis on ``centroids`` (and ``x``/``mask``)
    and composes with ``jax.vmap``; see the module docstring for the
    backend registry and ``mask`` contract.

    Block resolution: an explicit ``block_n`` always wins; otherwise an
    active autotune cache (``kernels.autotune.tuning`` scope — what
    ``EngineConfig(autotune=True)`` enters) supplies the tuned block for
    this (backend, shape) cell; with neither, the backend's hand-picked
    ``TilePolicy`` default applies, bit-for-bit as before.  Either way
    the block passes through ``TilePolicy.block_for`` alignment.
    """
    b = dispatch.resolve_backend(backend, interpret)
    pol = layout.tile_policy(b)
    n = x.shape[-2]
    if block_n is None:
        tuned = autotune.tuned_blocks(
            "kmeans_assign", b, n=n, k=centroids.shape[-2], d=x.shape[-1])
        if tuned:
            block_n = tuned.get("block_n")
    bn = pol.block_for(n, block_n)
    w = (jnp.ones(x.shape[:-1], jnp.float32) if mask is None
         else jnp.asarray(mask, jnp.float32))
    return _dispatched(bn, b)(x, w, centroids)


def kmeans_assign_chunked(x, centroids, *, chunks: int = 1, mask=None,
                          block_n: int | None = None,
                          backend: str | None = None,
                          interpret: bool | None = None):
    """Streaming entry point for the fused op (engine ``chunks`` mode).

    Slices N into statically-sized pieces via the shared chunked-call
    driver (``layout.chunked_sweep``), runs the dispatched op per piece,
    and accumulates the additive statistics — the [N, K] intermediate
    never exceeds one chunk.  Same contract as ``kmeans_assign``.
    """
    n = x.shape[-2]
    if chunks <= 1 or n <= 1:
        return kmeans_assign(x, centroids, mask=mask, block_n=block_n,
                             backend=backend, interpret=interpret)

    def call(a, b):
        return kmeans_assign(
            x[..., a:b, :], centroids,
            mask=None if mask is None else mask[..., a:b],
            block_n=block_n, backend=backend, interpret=interpret)

    return layout.chunked_sweep(call, n, chunks)
