"""jit'd public wrapper: pad → pallas_call → trim.

Padding policy (TPU alignment):
  D → multiple of 128 (vector lanes) with zeros — distances unchanged;
  K → multiple of 8 (sublanes) with +1e9 sentinel centroids — never argmin;
  N → multiple of block_n — masked out of statistics via static n_valid.

On CPU (this container) the kernel runs in interpret mode; on TPU it
compiles.  ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import kmeans_assign_kernel

_PAD_CENTROID = 1.0e9


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _padded_call(x, centroids, block_n: int, interpret: bool):
    n, d = x.shape
    k = centroids.shape[0]
    n_pad = _round_up(n, block_n)
    d_pad = _round_up(d, 128)
    k_pad = _round_up(k, 8)
    xp = jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, d_pad - d)))
    cp = jnp.pad(centroids.astype(jnp.float32),
                 ((0, k_pad - k), (0, d_pad - d)))
    if k_pad > k:  # sentinel rows: huge distance, never selected
        cp = cp.at[k:, :].set(_PAD_CENTROID)
    labels, sums, counts, j = kmeans_assign_kernel(
        xp, cp, n_valid=n, block_n=block_n, interpret=interpret)
    return labels[:n], sums[:k, :d], counts[:k], j[0]


def kmeans_assign(x, centroids, *, block_n: int = 1024,
                  interpret: bool | None = None):
    """Fused assignment: (labels [N] i32, sums [K,D], counts [K], j [])."""
    if interpret is None:
        interpret = _auto_interpret()
    n = x.shape[0]
    block_n = min(block_n, _round_up(max(n, 8), 8))
    return _padded_call(x, centroids, block_n, interpret)


def chunk_bounds(n: int, chunks: int) -> list[tuple[int, int]]:
    """Static [start, stop) slices covering N in ``chunks`` pieces; the last
    piece absorbs the remainder when chunks does not divide N."""
    c = max(1, min(int(chunks), n))
    per = -(-n // c)
    return [(s, min(s + per, n)) for s in range(0, n, per)]


def kmeans_assign_chunked(x, centroids, *, chunks: int = 1,
                          block_n: int = 1024,
                          interpret: bool | None = None):
    """Streaming entry point for the fused kernel (engine ``chunks`` mode).

    Slices N into statically-sized pieces, runs the kernel per piece (each
    call keeps the kernel's own n_valid masking), and accumulates the
    additive statistics — so the [N, K] intermediate never exceeds one
    chunk.  Same contract as ``kmeans_assign``.
    """
    n = x.shape[0]
    if chunks <= 1 or n <= 1:
        return kmeans_assign(x, centroids, block_n=block_n,
                             interpret=interpret)
    labels, sums, counts, j = [], None, None, None
    for a, b in chunk_bounds(n, chunks):
        lab, s, cnt, jj = kmeans_assign(x[a:b], centroids, block_n=block_n,
                                        interpret=interpret)
        labels.append(lab)
        sums = s if sums is None else sums + s
        counts = cnt if counts is None else counts + cnt
        j = jj if j is None else j + jj
    return jnp.concatenate(labels), sums, counts, j
