from . import ops, ref
from .ops import kmeans_assign
