"""Fused k-means assignment Pallas kernel (TPU target).

One pass over the points produces labels, per-cluster sums/counts and the
objective J.  The unfused baseline reads X three times (assign, accumulate,
objective); fusing gives arithmetic intensity ≈ 2K FLOP/byte on the distance
matmul plus the one-hot accumulation matmul — both MXU work.

Blocking: grid over N; each step holds an [T_N, D] tile of points plus the
full [K, D] centroid block in VMEM.  Reduction outputs (sums/counts/J) use a
constant index_map so every grid step accumulates into the same VMEM block
(TPU grids execute sequentially → safe accumulation).

Shapes are pre-padded by ops.py: D→mult of 128 (lanes), K→mult of 8
(sublanes), N→mult of block_n.  Padded centroid rows are +1e9 so no point
selects them; padded points are masked out of sums/counts/J via the
statically-known n_valid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, labels_ref, sums_ref, counts_ref, j_ref,
            *, n_valid: int, block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        j_ref[...] = jnp.zeros_like(j_ref)

    x = x_ref[...].astype(jnp.float32)            # [T, D]
    c = c_ref[...].astype(jnp.float32)            # [K, D]
    t, _ = x.shape
    k = c.shape[0]

    x2 = jnp.sum(x * x, axis=-1, keepdims=True)                  # [T, 1]
    c2 = jnp.sum(c * c, axis=-1)                                 # [K]
    d2 = x2 - 2.0 * jax.lax.dot(x, c.T,                           # MXU matmul
                                preferred_element_type=jnp.float32)
    d2 = d2 + c2[None, :]

    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)           # [T]
    mind2 = jnp.maximum(jnp.min(d2, axis=-1), 0.0)               # [T]

    # mask out padded points (row index ≥ n_valid); 2D iota for TPU
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0)[:, 0]
    valid = (step * block_n + rows) < n_valid                    # [T] bool
    w = valid.astype(jnp.float32)

    labels_ref[...] = jnp.where(valid, labels, -1)
    j_ref[...] += jnp.sum(mind2 * w)[None]

    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    onehot = (labels[:, None] == cols).astype(jnp.float32) * w[:, None]
    sums_ref[...] += jax.lax.dot(onehot.T, x,                    # [K, D] MXU
                                 preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)


def kmeans_assign_kernel(x: jnp.ndarray, centroids: jnp.ndarray, *,
                         n_valid: int, block_n: int = 1024,
                         interpret: bool = False):
    """Padded inputs → (labels [N], sums [K,D], counts [K], j [1])."""
    n, d = x.shape
    k = centroids.shape[0]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_kernel, n_valid=n_valid, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # points tile
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # centroids resident
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),       # labels
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # sums (accumulated)
            pl.BlockSpec((k,), lambda i: (0,)),             # counts (accumulated)
            pl.BlockSpec((1,), lambda i: (0,)),             # J (accumulated)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centroids)
