"""Fused k-means assignment Pallas kernel (TPU compiled / Triton on GPU /
interpreter elsewhere — ``ops.py`` dispatches via ``kernels.dispatch``).

One pass over the points produces labels, per-cluster sums/counts and the
objective J.  The unfused baseline reads X three times (assign, accumulate,
objective); fusing gives arithmetic intensity ≈ 2K FLOP/byte on the distance
matmul plus the one-hot accumulation matmul — both MXU work.

Grid: ``(R, N // block_n)`` — a leading **restart axis** so vmapped
multi-restart programs map onto the grid instead of needing a pallas-level
batching rule (``ops.py`` installs a ``custom_vmap`` that routes here).
R = 1 recovers the single-restart sweep.  The points (and their row-weight
mask) may be shared across restarts (index map pins their restart block to
0) or per-restart (minibatch draws differ per restart).

Row validity is a **mask operand** ``w`` (f32 row weights; 0 = padding),
replacing the old static ``n_valid`` — the same kernel now serves flat
sweeps, the engine's padded ``[C, P, D]`` chunk layout, and dynamically
drawn minibatch chunks without recompiling per remainder.

Accumulation: TPU grids execute sequentially with the last axis innermost,
so for ``accumulate=True`` the reduction outputs use a constant (per-r)
index map and every N-step accumulates into the same VMEM block, re-zeroed
at step 0 of each restart.  GPU (Triton) grid cells are parallel CTAs, so
``accumulate=False`` instead writes per-step partials ``[R, S, ...]`` that
the wrapper reduces with one ``jnp.sum`` — the standard split reduction.

Shapes are pre-padded by ops.py per the backend's ``layout.TilePolicy``;
padded centroid rows are +1e9 so no point selects them; padded point rows
carry weight 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, c_ref, labels_ref, sums_ref, counts_ref, j_ref,
            *, accumulate: bool):
    step = pl.program_id(1)

    if accumulate:
        @pl.when(step == 0)
        def _init():
            sums_ref[...] = jnp.zeros_like(sums_ref)
            counts_ref[...] = jnp.zeros_like(counts_ref)
            j_ref[...] = jnp.zeros_like(j_ref)

    x = x_ref[0].astype(jnp.float32)              # [T, D]
    w = w_ref[0].astype(jnp.float32)              # [T]
    c = c_ref[0].astype(jnp.float32)              # [K, D]
    t, _ = x.shape
    k = c.shape[0]

    x2 = jnp.sum(x * x, axis=-1, keepdims=True)                  # [T, 1]
    c2 = jnp.sum(c * c, axis=-1)                                 # [K]
    d2 = x2 - 2.0 * jax.lax.dot(x, c.T,                           # MXU matmul
                                preferred_element_type=jnp.float32)
    d2 = d2 + c2[None, :]

    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)           # [T]
    mind2 = jnp.maximum(jnp.min(d2, axis=-1), 0.0)               # [T]
    valid = w > 0.0

    labels_ref[...] = jnp.where(valid, labels, -1)[None]
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    onehot = (labels[:, None] == cols).astype(jnp.float32) * w[:, None]
    j_blk = jnp.sum(mind2 * w)
    sums_blk = jax.lax.dot(onehot.T, x,                          # [K, D] MXU
                           preferred_element_type=jnp.float32)
    counts_blk = jnp.sum(onehot, axis=0)
    if accumulate:
        j_ref[...] += j_blk[None, None]
        sums_ref[...] += sums_blk[None]
        counts_ref[...] += counts_blk[None]
    else:                                        # per-step partials (GPU)
        j_ref[...] = j_blk[None, None, None]
        sums_ref[...] = sums_blk[None, None]
        counts_ref[...] = counts_blk[None, None]


def kmeans_assign_kernel(x, w, centroids, *, block_n: int = 1024,
                         interpret: bool = False, accumulate: bool = True):
    """Padded inputs → fused stats over a (restarts, row-blocks) grid.

    x [Rx, Npad, Dpad] (Rx ∈ {1, R}: shared or per-restart points),
    w [Rw, Npad] row weights, centroids [R, Kpad, Dpad].  Returns
    (labels [R, Npad] i32, sums, counts, j) — reduction outputs are
    [R, ...] when ``accumulate`` else per-step partials [R, S, ...] for the
    wrapper to sum (parallel-grid backends).
    """
    rx, n, d = x.shape
    rw = w.shape[0]
    r, k, _ = centroids.shape
    assert n % block_n == 0, (n, block_n)
    assert rx in (1, r) and rw in (1, r), (rx, rw, r)
    s = n // block_n
    grid = (r, s)
    xi = (lambda ri, i: (ri, i, 0)) if rx == r and r > 1 \
        else (lambda ri, i: (0, i, 0))
    wi = (lambda ri, i: (ri, i)) if rw == r and r > 1 \
        else (lambda ri, i: (0, i))
    if accumulate:
        red_specs = [
            pl.BlockSpec((1, k, d), lambda ri, i: (ri, 0, 0)),   # sums
            pl.BlockSpec((1, k), lambda ri, i: (ri, 0)),         # counts
            pl.BlockSpec((1, 1), lambda ri, i: (ri, 0)),         # J
        ]
        red_shapes = [
            jax.ShapeDtypeStruct((r, k, d), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ]
    else:
        red_specs = [
            pl.BlockSpec((1, 1, k, d), lambda ri, i: (ri, i, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda ri, i: (ri, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda ri, i: (ri, i, 0)),
        ]
        red_shapes = [
            jax.ShapeDtypeStruct((r, s, k, d), jnp.float32),
            jax.ShapeDtypeStruct((r, s, k), jnp.float32),
            jax.ShapeDtypeStruct((r, s, 1), jnp.float32),
        ]
    return pl.pallas_call(
        functools.partial(_kernel, accumulate=accumulate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d), xi),              # points tile
            pl.BlockSpec((1, block_n), wi),                 # row weights
            pl.BlockSpec((1, k, d), lambda ri, i: (ri, 0, 0)),  # centroids
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda ri, i: (ri, i)),  # labels
            *red_specs,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.int32),
            *red_shapes,
        ],
        interpret=interpret,
    )(x, w, centroids)
