"""Flash attention Pallas kernel (dispatched per backend by ``ops.py`` —
TPU compiled, Triton on GPU, interpreter elsewhere) — GQA, causal /
sliding-window / bidirectional, online softmax.

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks); the kv dimension is the
innermost (sequential on TPU) so the online-softmax state for one q tile
lives in VMEM scratch across kv steps: m (running max), l (running sum),
acc (unnormalised output).  K/V BlockSpecs map q-head → kv-head via
h // (Hq // Hkv), which implements GQA with no K/V duplication in HBM.

Masking is positional: with q tile offset qo and kv tile offset ko,
    causal:          q_idx ≥ k_idx
    sliding window:  q_idx − w < k_idx ≤ q_idx
    bidirectional:   all pairs
Fully-masked kv tiles are skipped with @pl.when (no MXU work) — this is what
makes the causal kernel ~2× the naive blocked cost, and the sliding-window
kernel O(S·w).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 *, scale: float, causal: bool, window: int | None,
                 block_q: int, block_k: int, n_kv_blocks: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_off = qi * block_q
    k_off = ki * block_k

    # tile-level skip: run only if some (q, k) pair in this tile is visible
    if window is not None:
        run = jnp.logical_and(q_off + block_q - 1 >= k_off,
                              q_off - window < k_off + block_k)
    elif causal:
        run = q_off + block_q - 1 >= k_off
    else:
        run = jnp.asarray(True)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [Tq, dh]
        k = k_ref[0, 0].astype(jnp.float32)                # [Tk, dh]
        v = v_ref[0, 0].astype(jnp.float32)                # [Tk, dh]
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)

        rows = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < seq_len                              # kv padding
        if causal or window is not None:
            mask = jnp.logical_and(mask, rows >= cols)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool, window: int | None,
                           scale: float, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q [B,Hq,Sq,dh], k/v [B,Hkv,Skv,dh] (pre-padded) → o [B,Hq,Sq,dh]."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert sq % block_q == 0 and skv % block_k == 0
    group = hq // hkv
    n_q, n_kv = sq // block_q, skv // block_k
    grid = (b, hq, n_q, n_kv)
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_kv_blocks=n_kv, seq_len=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # m: running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # l: running sum
            pltpu.VMEM((block_q, dh), jnp.float32),   # acc: unnormalised out
        ],
        interpret=interpret,
    )(q, k, v)
