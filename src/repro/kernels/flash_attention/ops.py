"""jit'd public wrapper for flash attention: pad seq/head-dim → kernel → trim.

Padding: Sq/Skv → multiples of the block sizes (padded kv columns are masked
inside the kernel via seq_len; padded q rows produce garbage rows that are
trimmed); dh → multiple of 128 with zeros (contributes nothing to scores).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def _padded_call(q, k, v, causal, window, scale, block_q, block_k, interpret):
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    sq_p = _round_up(sq, block_q)
    sk_p = _round_up(skv, block_k)
    dh_p = _round_up(dh, 128)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, dh_p - dh)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - skv), (0, dh_p - dh)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - skv), (0, dh_p - dh)))
    o = flash_attention_kernel(qp, kp, vp, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return o[:, :, :sq, :dh]


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """Flash attention with GQA: q [B,Hq,S,dh], k/v [B,Hkv,S,dh]."""
    if interpret is None:
        interpret = _auto_interpret()
    if scale is None:
        scale = q.shape[-1] ** -0.5
    block_q = min(block_q, _round_up(q.shape[2], 8))
    block_k = min(block_k, _round_up(k.shape[2], 8))
    return _padded_call(q, k, v, causal, window, float(scale),
                        block_q, block_k, interpret)
