"""Public flash-attention op, dispatched through the backend registry.

Backends (see ``kernels.dispatch``): ``tpu`` compiles the Pallas kernel
(Mosaic), ``interpret`` runs the same kernel under the interpreter (CPU
CI), and ``xla`` is the exact-softmax reference.  **No ``gpu`` backend is
registered**: the kernel carries its online-softmax state in TPU VMEM
scratch across the sequential innermost kv grid axis, which is invalid
under Triton's parallel CTAs (the clustering kernels got an
``accumulate=False`` split-reduction variant for exactly this reason; a
Triton-safe flash variant is future work) — on a GPU host the registry
fails loud with the available list; pass ``backend="xla"`` there.
Padding: Sq/Skv → multiples of the block sizes (padded kv columns are
masked inside the kernel via seq_len; padded q rows produce garbage rows
that are trimmed); dh → multiple of 128 with zeros (contributes nothing
to scores).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune, dispatch, layout
from repro.kernels.layout import round_up

from .kernel import flash_attention_kernel
from .ref import attention_ref

OP = dispatch.get_op("flash_attention")

# sequential-grid Pallas backends only — see the module docstring for why
# there is no "gpu" registration
_SEQ_GRID_BACKENDS = ("tpu", "interpret")


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "backend"))
def _pallas_impl(q, k, v, *, causal, window, scale, block_q, block_k,
                 backend):
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    sq_p = round_up(sq, block_q)
    sk_p = round_up(skv, block_k)
    dh_p = round_up(dh, 128)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, dh_p - dh)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - skv), (0, dh_p - dh)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - skv), (0, dh_p - dh)))
    o = flash_attention_kernel(qp, kp, vp, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=(backend == "interpret"))
    return o[:, :, :sq, :dh]


for _b in _SEQ_GRID_BACKENDS:
    OP.register(_b)(functools.partial(_pallas_impl, backend=_b))


@OP.register("xla")
@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k"))
def _xla_impl(q, k, v, *, causal, window, scale, block_q, block_k):
    del block_q, block_k
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int | None = None,
                    block_k: int | None = None,
                    backend: str | None = None,
                    interpret: bool | None = None):
    """Flash attention with GQA: q [B,Hq,S,dh], k/v [B,Hkv,S,dh].

    Block resolution mirrors the clustering ops: explicit ``block_q`` /
    ``block_k`` win; else an active autotune cache
    (``kernels.autotune.tuning`` scope) supplies the tuned pair for this
    (backend, Sq, Skv, dh) cell; else the hand-picked 128×128 default —
    all capped to the aligned sequence lengths as before.
    """
    b = dispatch.resolve_backend(backend, interpret)
    pol = layout.tile_policy(b)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    tuned = None
    if block_q is None and block_k is None:
        tuned = autotune.tuned_blocks(
            "flash_attention", b, n=q.shape[2], k=k.shape[2], d=q.shape[3])
    bq = block_q if block_q is not None else (tuned or {}).get("block_q", 128)
    bk = block_k if block_k is not None else (tuned or {}).get("block_k", 128)
    bq = min(bq, round_up(q.shape[2], pol.row_align))
    bk = min(bk, round_up(k.shape[2], pol.row_align))
    _, fn = OP.impl(b)
    return fn(q, k, v, causal=causal, window=window, scale=float(scale),
              block_q=bq, block_k=bk)
