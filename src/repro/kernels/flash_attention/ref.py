"""Pure-jnp oracle for flash attention (GQA, causal/sliding/bidirectional)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool, window: int | None = None,
                  scale: float | None = None):
    """q [B,Hq,Sq,dh], k/v [B,Hkv,Skv,dh] → [B,Hq,Sq,dh]; exact softmax."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    if scale is None:
        scale = dh ** -0.5
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal or window is not None:
        mask = rows >= cols
    if window is not None:
        mask = jnp.logical_and(mask, cols > rows - window)
    s = jnp.where(mask[None, None], s, -1.0e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
