"""Pure-jnp oracle for the fused GMM E-step kernel.

``gmm_estep_masked_ref`` is the one copy of the reference math — the
registered ``xla`` backend delegates here (so the test oracle and the
backend users run with ``kernel_backend="xla"`` cannot drift), and the
historical ``gmm_estep_ref`` signature wraps it with unit weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_LOG2PI = 1.8378770664093453


def gmm_estep_masked_ref(x, w, means, var, log_w):
    """(labels [N] i32, loglik [], r_sum [K], r_x [K,D], r_x2 [K,D]).

    ``w`` are f32 row weights; weight-0 rows are labelled -1 and carry no
    statistics — the kernel ops' mask contract.
    """
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    inv_var = 1.0 / var
    quad = ((x * x) @ inv_var.T
            - 2.0 * (x @ (means * inv_var).T)
            + jnp.sum(means ** 2 * inv_var, axis=-1)[None, :])
    log_det = jnp.sum(jnp.log(var), axis=-1)
    d = x.shape[-1]
    lp = log_w[None, :] - 0.5 * (quad + log_det[None, :] + d * _LOG2PI)
    lse = jax.scipy.special.logsumexp(lp, axis=-1)
    resp = jnp.exp(lp - lse[:, None]) * w[:, None]
    labels = jnp.argmax(lp, axis=-1).astype(jnp.int32)
    return (jnp.where(w > 0, labels, -1), jnp.sum(lse * w),
            jnp.sum(resp, axis=0), resp.T @ x, resp.T @ (x * x))


def gmm_estep_ref(x, means, var, log_w):
    """(labels [N] i32, loglik [1], r_sum [K], r_x [K,D], r_x2 [K,D])."""
    labels, loglik, r_sum, r_x, r_x2 = gmm_estep_masked_ref(
        x, jnp.ones((x.shape[0],), jnp.float32), means, var, log_w)
    return labels, loglik[None], r_sum, r_x, r_x2
