"""Pure-jnp oracle for the fused GMM E-step kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_LOG2PI = 1.8378770664093453


def gmm_estep_ref(x, means, var, log_w):
    """(labels [N] i32, loglik [1], r_sum [K], r_x [K,D], r_x2 [K,D])."""
    x = x.astype(jnp.float32)
    inv_var = 1.0 / var
    quad = ((x * x) @ inv_var.T
            - 2.0 * (x @ (means * inv_var).T)
            + jnp.sum(means ** 2 * inv_var, axis=-1)[None, :])
    log_det = jnp.sum(jnp.log(var), axis=-1)
    d = x.shape[-1]
    lp = log_w[None, :] - 0.5 * (quad + log_det[None, :] + d * _LOG2PI)
    lse = jax.scipy.special.logsumexp(lp, axis=-1)
    resp = jnp.exp(lp - lse[:, None])
    labels = jnp.argmax(lp, axis=-1).astype(jnp.int32)
    return (labels, jnp.sum(lse)[None], jnp.sum(resp, axis=0),
            resp.T @ x, resp.T @ (x * x))
