"""Fused diagonal-GMM E-step Pallas kernel (TPU compiled / Triton on GPU /
interpreter elsewhere — ``ops.py`` dispatches via ``kernels.dispatch``).

Per tile of points, computes component log-densities via the matmul
decomposition  lp = const_k − 0.5·x²·(1/σ²)ᵀ + x·(μ/σ²)ᵀ,  then log-sum-exp,
responsibilities, labels, and ALL M-step sufficient statistics (Σr, Σr·x,
Σr·x²) — one HBM read of the points per EM iteration instead of four.

Grid: ``(R, N // block_n)`` with a leading restart axis (see the
kmeans_assign kernel header; same contract: points/weights shared or
per-restart, parameters per-restart, R = 1 for single fits).  Row validity
is the ``w`` mask operand.  ``accumulate=False`` writes per-step partials
for parallel-grid (GPU) backends; the wrapper sums them.

ops.py pre-computes the [R,K,D] operand matrices and the per-component
constant (log w − ½(Σμ²/σ² + Σlog σ² + D·log 2π)), and pads per the
backend's ``layout.TilePolicy``:
  D → lane multiple with inv_var = 0 (padded dims contribute nothing),
  K → sublane multiple with const = −1e30 (zero responsibility),
  N → ×block_n with weight 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, a_ref, b_ref, const_ref,
            labels_ref, loglik_ref, rsum_ref, rx_ref, rx2_ref,
            *, accumulate: bool):
    step = pl.program_id(1)

    if accumulate:
        @pl.when(step == 0)
        def _init():
            loglik_ref[...] = jnp.zeros_like(loglik_ref)
            rsum_ref[...] = jnp.zeros_like(rsum_ref)
            rx_ref[...] = jnp.zeros_like(rx_ref)
            rx2_ref[...] = jnp.zeros_like(rx2_ref)

    x = x_ref[0].astype(jnp.float32)          # [T, D]
    w = w_ref[0].astype(jnp.float32)          # [T]
    a = a_ref[0]                              # [K, D] = 1/σ²
    b = b_ref[0]                              # [K, D] = μ/σ²
    const = const_ref[0]                      # [K]

    xx = x * x
    lp = (const[None, :]
          - 0.5 * jax.lax.dot(xx, a.T, preferred_element_type=jnp.float32)
          + jax.lax.dot(x, b.T, preferred_element_type=jnp.float32))  # [T,K]

    m = jnp.max(lp, axis=-1, keepdims=True)                  # online-safe LSE
    e = jnp.exp(lp - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    lse = (m + jnp.log(s))[:, 0]                             # [T]
    resp = e / s                                             # [T, K]
    labels = jnp.argmax(lp, axis=-1).astype(jnp.int32)
    valid = w > 0.0
    respw = resp * w[:, None]

    labels_ref[...] = jnp.where(valid, labels, -1)[None]
    ll_blk = jnp.sum(lse * w)
    rsum_blk = jnp.sum(respw, axis=0)
    rx_blk = jax.lax.dot(respw.T, x, preferred_element_type=jnp.float32)
    rx2_blk = jax.lax.dot(respw.T, xx, preferred_element_type=jnp.float32)
    if accumulate:
        loglik_ref[...] += ll_blk[None, None]
        rsum_ref[...] += rsum_blk[None]
        rx_ref[...] += rx_blk[None]
        rx2_ref[...] += rx2_blk[None]
    else:                                    # per-step partials (GPU)
        loglik_ref[...] = ll_blk[None, None, None]
        rsum_ref[...] = rsum_blk[None, None]
        rx_ref[...] = rx_blk[None, None]
        rx2_ref[...] = rx2_blk[None, None]


def gmm_estep_kernel(x, w, a, b, const, *, block_n: int = 1024,
                     interpret: bool = False, accumulate: bool = True):
    """Padded operands → fused E-step stats over a (restarts, rows) grid.

    x [Rx, Npad, Dpad], w [Rw, Npad], a/b [R, Kpad, Dpad], const [R, Kpad]
    (Rx, Rw ∈ {1, R}).  Returns (labels [R, Npad], loglik, r_sum, r_x,
    r_x2) with reduction outputs [R, ...] when ``accumulate`` else
    per-step partials [R, S, ...].
    """
    rx_, n, d = x.shape
    rw = w.shape[0]
    r, k, _ = a.shape
    assert n % block_n == 0, (n, block_n)
    assert rx_ in (1, r) and rw in (1, r), (rx_, rw, r)
    s = n // block_n
    grid = (r, s)
    xi = (lambda ri, i: (ri, i, 0)) if rx_ == r and r > 1 \
        else (lambda ri, i: (0, i, 0))
    wi = (lambda ri, i: (ri, i)) if rw == r and r > 1 \
        else (lambda ri, i: (0, i))
    if accumulate:
        red_specs = [
            pl.BlockSpec((1, 1), lambda ri, i: (ri, 0)),         # loglik
            pl.BlockSpec((1, k), lambda ri, i: (ri, 0)),         # r_sum
            pl.BlockSpec((1, k, d), lambda ri, i: (ri, 0, 0)),   # r_x
            pl.BlockSpec((1, k, d), lambda ri, i: (ri, 0, 0)),   # r_x2
        ]
        red_shapes = [
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k, d), jnp.float32),
            jax.ShapeDtypeStruct((r, k, d), jnp.float32),
        ]
    else:
        red_specs = [
            pl.BlockSpec((1, 1, 1), lambda ri, i: (ri, i, 0)),
            pl.BlockSpec((1, 1, k), lambda ri, i: (ri, i, 0)),
            pl.BlockSpec((1, 1, k, d), lambda ri, i: (ri, i, 0, 0)),
            pl.BlockSpec((1, 1, k, d), lambda ri, i: (ri, i, 0, 0)),
        ]
        red_shapes = [
            jax.ShapeDtypeStruct((r, s, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, s, k), jnp.float32),
            jax.ShapeDtypeStruct((r, s, k, d), jnp.float32),
            jax.ShapeDtypeStruct((r, s, k, d), jnp.float32),
        ]
    return pl.pallas_call(
        functools.partial(_kernel, accumulate=accumulate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d), xi),
            pl.BlockSpec((1, block_n), wi),
            pl.BlockSpec((1, k, d), lambda ri, i: (ri, 0, 0)),
            pl.BlockSpec((1, k, d), lambda ri, i: (ri, 0, 0)),
            pl.BlockSpec((1, k), lambda ri, i: (ri, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda ri, i: (ri, i)),
            *red_specs,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.int32),
            *red_shapes,
        ],
        interpret=interpret,
    )(x, w, a, b, const)
