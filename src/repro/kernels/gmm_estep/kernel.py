"""Fused diagonal-GMM E-step Pallas kernel (TPU target).

Per tile of points, computes component log-densities via the matmul
decomposition  lp = const_k − 0.5·x²·(1/σ²)ᵀ + x·(μ/σ²)ᵀ,  then log-sum-exp,
responsibilities, labels, and ALL M-step sufficient statistics (Σr, Σr·x,
Σr·x²) — one HBM read of the points per EM iteration instead of four.

ops.py pre-computes the [K,D] operand matrices and the per-component constant
(log w − ½(Σμ²/σ² + Σlog σ² + D·log 2π)), and pads:
  D → ×128 with inv_var = 0 (padded dims contribute nothing),
  K → ×8 with const = −1e30 (zero responsibility),
  N → ×block_n, masked by static n_valid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, const_ref,
            labels_ref, loglik_ref, rsum_ref, rx_ref, rx2_ref,
            *, n_valid: int, block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        loglik_ref[...] = jnp.zeros_like(loglik_ref)
        rsum_ref[...] = jnp.zeros_like(rsum_ref)
        rx_ref[...] = jnp.zeros_like(rx_ref)
        rx2_ref[...] = jnp.zeros_like(rx2_ref)

    x = x_ref[...].astype(jnp.float32)        # [T, D]
    a = a_ref[...]                            # [K, D] = 1/σ²
    b = b_ref[...]                            # [K, D] = μ/σ²
    const = const_ref[...]                    # [K]
    t = x.shape[0]

    xx = x * x
    lp = (const[None, :]
          - 0.5 * jax.lax.dot(xx, a.T, preferred_element_type=jnp.float32)
          + jax.lax.dot(x, b.T, preferred_element_type=jnp.float32))  # [T,K]

    m = jnp.max(lp, axis=-1, keepdims=True)                  # online-safe LSE
    e = jnp.exp(lp - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    lse = (m + jnp.log(s))[:, 0]                             # [T]
    resp = e / s                                             # [T, K]
    labels = jnp.argmax(lp, axis=-1).astype(jnp.int32)

    rows = jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0)[:, 0]
    valid = (step * block_n + rows) < n_valid
    w = valid.astype(jnp.float32)
    respw = resp * w[:, None]

    labels_ref[...] = jnp.where(valid, labels, -1)
    loglik_ref[...] += jnp.sum(lse * w)[None]
    rsum_ref[...] += jnp.sum(respw, axis=0)
    rx_ref[...] += jax.lax.dot(respw.T, x, preferred_element_type=jnp.float32)
    rx2_ref[...] += jax.lax.dot(respw.T, xx, preferred_element_type=jnp.float32)


def gmm_estep_kernel(x, a, b, const, *, n_valid: int, block_n: int = 1024,
                     interpret: bool = False):
    n, d = x.shape
    k = a.shape[0]
    assert n % block_n == 0
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_kernel, n_valid=n_valid, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, a, b, const)
