"""jit'd public wrapper for the GMM E-step kernel: precompute + pad + trim."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import gmm_estep_kernel

_LOG2PI = 1.8378770664093453
_NEG = -1.0e30


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _padded_call(x, means, var, log_w, block_n: int, interpret: bool):
    n, d = x.shape
    k = means.shape[0]
    inv_var = 1.0 / var
    a = (means * inv_var).astype(jnp.float32)          # b operand: μ/σ²
    const = (log_w - 0.5 * (jnp.sum(means ** 2 * inv_var, axis=-1)
                            + jnp.sum(jnp.log(var), axis=-1)
                            + d * _LOG2PI)).astype(jnp.float32)
    n_pad = _round_up(n, block_n)
    d_pad = _round_up(d, 128)
    k_pad = _round_up(k, 8)
    xp = jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, d_pad - d)))
    ap = jnp.pad(inv_var.astype(jnp.float32), ((0, k_pad - k), (0, d_pad - d)))
    bp = jnp.pad(a, ((0, k_pad - k), (0, d_pad - d)))
    cp = jnp.pad(const, (0, k_pad - k), constant_values=_NEG)
    labels, loglik, r_sum, r_x, r_x2 = gmm_estep_kernel(
        xp, ap, bp, cp, n_valid=n, block_n=block_n, interpret=interpret)
    return (labels[:n], loglik[0], r_sum[:k], r_x[:k, :d], r_x2[:k, :d])


def gmm_estep(x, means, var, log_w, *, block_n: int = 1024,
              interpret: bool | None = None):
    """Fused E-step: (labels, loglik [], r_sum [K], r_x [K,D], r_x2 [K,D])."""
    if interpret is None:
        interpret = _auto_interpret()
    n = x.shape[0]
    block_n = min(block_n, _round_up(max(n, 8), 8))
    return _padded_call(x, means, var, log_w, block_n, interpret)


def gmm_estep_chunked(x, means, var, log_w, *, chunks: int = 1,
                      block_n: int = 1024, interpret: bool | None = None):
    """Streaming entry point for the fused E-step (engine ``chunks`` mode).

    Statically slices N, runs the kernel per slice, accumulates the additive
    sufficient statistics.  Same contract as ``gmm_estep``.
    """
    from repro.kernels.kmeans_assign.ops import chunk_bounds
    n = x.shape[0]
    if chunks <= 1 or n <= 1:
        return gmm_estep(x, means, var, log_w, block_n=block_n,
                         interpret=interpret)
    labels, loglik, r_sum, r_x, r_x2 = [], None, None, None, None
    for a, b in chunk_bounds(n, chunks):
        lab, ll, rs, rx, rx2 = gmm_estep(x[a:b], means, var, log_w,
                                         block_n=block_n, interpret=interpret)
        labels.append(lab)
        loglik = ll if loglik is None else loglik + ll
        r_sum = rs if r_sum is None else r_sum + rs
        r_x = rx if r_x is None else r_x + rx
        r_x2 = rx2 if r_x2 is None else r_x2 + rx2
    return jnp.concatenate(labels), loglik, r_sum, r_x, r_x2
