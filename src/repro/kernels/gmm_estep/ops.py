"""Public GMM E-step op, dispatched through the backend registry.

Same dispatch surface as ``kmeans_assign.ops`` (see that module header):
``tpu``/``gpu`` compile the Pallas kernel, ``interpret`` runs it under the
interpreter (CPU CI), ``xla`` is the pure-jnp reference contract; the
Pallas backends pre-compute the matmul-decomposition operands and pad per
``layout.TilePolicy``.  A ``custom_vmap`` rule maps ``jax.vmap`` (the
engine's multi-restart driver) onto the kernel grid's restart axis, and
``mask`` is an optional [N] f32 row-weight vector (0 drops the row and
labels it -1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune, dispatch, layout

from .kernel import gmm_estep_kernel

_LOG2PI = 1.8378770664093453
_NEG = -1.0e30

OP = dispatch.get_op("gmm_estep")


# --------------------------------------------------------------------------
# Backend implementations.  Shared internal contract:
#   impl(x, w, means, var, log_w, *, block_n)
#     -> (labels, loglik, r_sum, r_x, r_x2)
# with x [N, D] | [R, N, D], w [N] | [R, N], params [K, ...] | [R, K, ...];
# outputs carry the leading R iff the parameters do.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_n", "backend"))
def _pallas_impl(x, w, means, var, log_w, *, block_n: int, backend: str):
    pol = layout.tile_policy(backend)
    batched = means.ndim == 3
    mu = means if batched else means[None]
    vr = var if batched else var[None]
    lw = log_w if batched else log_w[None]
    x3 = x if x.ndim == 3 else x[None]
    w2 = w if w.ndim == 2 else w[None]
    if mu.ndim != 3 or x3.ndim != 3:
        raise NotImplementedError(
            "gmm_estep supports one leading restart axis at most; "
            f"got x {x.shape}, means {means.shape}")
    n, d = x3.shape[1:]
    k = mu.shape[1]
    inv_var = 1.0 / vr
    b_op = (mu * inv_var).astype(jnp.float32)          # b operand: μ/σ²
    const = (lw - 0.5 * (jnp.sum(mu ** 2 * inv_var, axis=-1)
                         + jnp.sum(jnp.log(vr), axis=-1)
                         + d * _LOG2PI)).astype(jnp.float32)
    n_pad = layout.round_up(n, block_n)
    d_pad = pol.align_d(d)
    k_pad = pol.align_k(k)
    xp = jnp.pad(x3.astype(jnp.float32),
                 ((0, 0), (0, n_pad - n), (0, d_pad - d)))
    wp = jnp.pad(w2.astype(jnp.float32), ((0, 0), (0, n_pad - n)))
    ap = jnp.pad(inv_var.astype(jnp.float32),
                 ((0, 0), (0, k_pad - k), (0, d_pad - d)))
    bp = jnp.pad(b_op, ((0, 0), (0, k_pad - k), (0, d_pad - d)))
    cp = jnp.pad(const, ((0, 0), (0, k_pad - k)), constant_values=_NEG)
    if backend == "gpu":   # parallel grid cells: split reduction
        labels, loglik, r_sum, r_x, r_x2 = gmm_estep_kernel(
            xp, wp, ap, bp, cp, block_n=block_n, interpret=False,
            accumulate=False)
        loglik, r_sum, r_x, r_x2 = (jnp.sum(loglik, axis=1),
                                    jnp.sum(r_sum, axis=1),
                                    jnp.sum(r_x, axis=1),
                                    jnp.sum(r_x2, axis=1))
    else:
        labels, loglik, r_sum, r_x, r_x2 = gmm_estep_kernel(
            xp, wp, ap, bp, cp, block_n=block_n,
            interpret=(backend == "interpret"))
    labels, loglik = labels[:, :n], loglik[:, 0]
    r_sum, r_x, r_x2 = r_sum[:, :k], r_x[:, :k, :d], r_x2[:, :k, :d]
    if not batched:
        labels, loglik = labels[0], loglik[0]
        r_sum, r_x, r_x2 = r_sum[0], r_x[0], r_x2[0]
    return labels, loglik, r_sum, r_x, r_x2


for _b in dispatch.PALLAS_BACKENDS:
    OP.register(_b)(functools.partial(_pallas_impl, backend=_b))


@OP.register("xla")
@functools.partial(jax.jit, static_argnames=("block_n",))
def _xla_impl(x, w, means, var, log_w, *, block_n: int):
    # delegates to the ref oracle (one copy of the math — see ref.py)
    del block_n
    from .ref import gmm_estep_masked_ref
    if means.ndim == 2:
        return gmm_estep_masked_ref(x, w, means, var, log_w)
    return jax.vmap(gmm_estep_masked_ref,
                    in_axes=(0 if x.ndim == 3 else None,
                             0 if w.ndim == 2 else None,
                             0, 0, 0))(x, w, means, var, log_w)


# --------------------------------------------------------------------------
# Public op (+ the custom_vmap restart-axis rule)
# --------------------------------------------------------------------------

# (block_n, backend) → custom_vmap-wrapped call; the restart-axis batching
# rule lives in dispatch.make_dispatched_factory (shared with kmeans_assign)
_dispatched = dispatch.make_dispatched_factory(OP, n_out=5)


def gmm_estep(x, means, var, log_w, *, mask=None, block_n: int | None = None,
              backend: str | None = None, interpret: bool | None = None):
    """Fused E-step: (labels, loglik [], r_sum [K], r_x [K,D], r_x2 [K,D]).

    Accepts a leading restart axis on the parameters (and ``x``/``mask``)
    and composes with ``jax.vmap``; see the module docstring.

    Block resolution mirrors ``kmeans_assign``: explicit ``block_n`` >
    active autotune cache (``kernels.autotune.tuning`` scope) >
    ``TilePolicy`` default — always ``block_for``-aligned.
    """
    b = dispatch.resolve_backend(backend, interpret)
    pol = layout.tile_policy(b)
    n = x.shape[-2]
    if block_n is None:
        tuned = autotune.tuned_blocks(
            "gmm_estep", b, n=n, k=means.shape[-2], d=x.shape[-1])
        if tuned:
            block_n = tuned.get("block_n")
    bn = pol.block_for(n, block_n)
    w = (jnp.ones(x.shape[:-1], jnp.float32) if mask is None
         else jnp.asarray(mask, jnp.float32))
    return _dispatched(bn, b)(x, w, means, var, log_w)


def gmm_estep_chunked(x, means, var, log_w, *, chunks: int = 1, mask=None,
                      block_n: int | None = None,
                      backend: str | None = None,
                      interpret: bool | None = None):
    """Streaming entry point for the fused E-step (engine ``chunks`` mode).

    Statically slices N via the shared chunked-call driver
    (``layout.chunked_sweep``), runs the dispatched op per slice,
    accumulates the additive sufficient statistics.  Same contract as
    ``gmm_estep``.
    """
    n = x.shape[-2]
    if chunks <= 1 or n <= 1:
        return gmm_estep(x, means, var, log_w, mask=mask, block_n=block_n,
                         backend=backend, interpret=interpret)

    def call(a, b):
        return gmm_estep(
            x[..., a:b, :], means, var, log_w,
            mask=None if mask is None else mask[..., a:b],
            block_n=block_n, backend=backend, interpret=interpret)

    return layout.chunked_sweep(call, n, chunks)
