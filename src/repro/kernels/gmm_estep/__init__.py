from . import ops, ref
from .ops import gmm_estep
