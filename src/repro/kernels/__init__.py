"""Pallas kernels for the framework's compute hot-spots, behind one
backend-dispatching layer (``dispatch.py`` + ``layout.py``).

kmeans_assign  — fused k-means assignment + statistics (paper's inner loop)
gmm_estep      — fused diagonal-GMM E-step + M-step sufficient statistics
flash_attention— GQA flash attention (causal / sliding-window / bidirectional)

Each package: kernel.py (pl.pallas_call + BlockSpec, restart-axis grid for
the clustering ops), ops.py (public wrapper: per-backend padding +
registry dispatch), ref.py (pure-jnp oracle for tests).  Registered
backends per op: ``tpu`` (Mosaic-compiled), ``gpu`` (Triton lowering, GPU
tile policy), ``interpret`` (same kernel under the Pallas interpreter —
the CPU CI path), ``xla`` (reference contract).  ``dispatch.force_backend``
/ ``register_backend`` let tests pin or extend any path.
"""
