"""Pallas TPU kernels for the framework's compute hot-spots.

kmeans_assign  — fused k-means assignment + statistics (paper's inner loop)
gmm_estep      — fused diagonal-GMM E-step + M-step sufficient statistics
flash_attention— GQA flash attention (causal / sliding-window / bidirectional)

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper
with padding; interpret=True on CPU), ref.py (pure-jnp oracle for tests).
"""
