"""Roofline-driven kernel autotuner (ISSUE 9).

The ``TilePolicy`` block sizes in :mod:`repro.kernels.layout` are
hand-picked; this module measures them.  For each registered
:class:`~repro.kernels.dispatch.KernelOp` it sweeps candidate block
shapes (a row-block grid aligned per the backend's ``TilePolicy``,
including the Triton power-of-two rule), times every candidate with the
shared methodology (warmup + ``block_until_ready`` + median-of-k, from
:mod:`repro.kernels.timing`), attaches analytic FLOP/byte counts (the
``analysis.hlo_ir`` Cost walker over the op's compiled ``xla`` reference
at the same shape — backend-independent math), and caches winners in a
versioned JSON keyed by ``(op, backend, device_kind, problem-shape
bucket)``.

Resolution contract (the ops consult :func:`tuned_blocks`):

  · an explicit ``block_n=`` / ``block_q=`` / ``block_k=`` argument
    always wins — the cache is never consulted;
  · no active cache (or no matching entry) → the hand-picked
    ``TilePolicy`` defaults, bit-for-bit unchanged;
  · an active cache entry supplies the blocks, which still pass through
    ``TilePolicy.block_for`` so a cached shape can never violate the
    backend's alignment rules.

Activation is scoped: ``with autotune.tuning(cache): ...`` (what
``EngineConfig(autotune=True)`` does around every fit driver, using
:func:`default_cache`).  The lookup happens at *trace* time, so a config
with ``autotune=True`` traces separately from the untuned one (the flag
is part of the static jit key); swapping caches mid-process requires
``jax.clear_caches()`` to drop traces that baked in the old blocks.

Winner selection is deterministic: candidates are generated in a fixed
order with the default first, timed with one methodology, and the
argmin (first on ties) wins — so the tuned median is by construction
≤ the default's *from the same sweep*, which is what the
``BENCH_roofline.json`` tuned-vs-default ≥ 1.0× claim gates.
"""
from __future__ import annotations

import contextlib
import functools
import importlib
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch, layout
from repro.kernels.timing import time_callable

SCHEMA_VERSION = 1

# ops this tuner knows how to drive (shape triple semantics per op:
# clustering = rows × clusters × features; flash = Sq × Skv × head_dim)
SUPPORTED_OPS = ("kmeans_assign", "gmm_estep", "flash_attention")

# row-block candidate grid; each entry passes through TilePolicy.block_for
# so alignment (incl. the Triton pow2 rule) and the n-cap are enforced
ROW_BLOCK_GRID = (128, 256, 512, 1024, 2048)
FLASH_BLOCK_GRID = (64, 128, 256)

DEFAULT_SHAPES: dict[str, tuple[tuple[int, int, int], ...]] = {
    "kmeans_assign": ((16384, 8, 16), (65536, 8, 4)),
    "gmm_estep": ((16384, 8, 16),),
    "flash_attention": ((512, 512, 64),),
}

_FLASH_HEADS = 2  # fixed head count for flash sweep operands (B=1)


class StaleCacheError(ValueError):
    """An on-disk cache written under a different schema version."""


def device_kind() -> str:
    """The host accelerator's device kind, as a cache-key token."""
    return jax.devices()[0].device_kind.replace(" ", "_")


# --------------------------------------------------------------------------
# The versioned winner cache
# --------------------------------------------------------------------------

class AutotuneCache:
    """Winners keyed by ``op|backend|device_kind|n-bucket|k|d``.

    The row count is bucketed through :func:`layout.bucket_for` (the
    serving layer's closed shape ladder, which above the largest bucket
    continues in multiples of it), so one tuned entry serves every
    problem size that pads to the same compile shape; k and d are exact.
    """

    def __init__(self, entries: dict | None = None):
        self.entries: dict[str, dict] = dict(entries or {})

    @staticmethod
    def key(op: str, backend: str, *, n: int, k: int, d: int,
            kind: str | None = None) -> str:
        kind = kind if kind is not None else device_kind()
        return f"{op}|{backend}|{kind}|n{layout.bucket_for(n)}|k{k}|d{d}"

    def put(self, op: str, backend: str, *, n: int, k: int, d: int,
            blocks: dict, **meta) -> str:
        key = self.key(op, backend, n=n, k=k, d=d)
        self.entries[key] = {
            "op": op, "backend": backend, "device_kind": device_kind(),
            "n_bucket": layout.bucket_for(n), "k": k, "d": d,
            "blocks": {name: int(v) for name, v in blocks.items()},
            **meta,
        }
        return key

    def lookup(self, op: str, backend: str, *, n: int, k: int,
               d: int) -> dict | None:
        """The winning blocks dict for this cell, or None (host
        device-kind keyed — a cache tuned on another device kind never
        matches)."""
        e = self.entries.get(self.key(op, backend, n=n, k=k, d=d))
        return dict(e["blocks"]) if e else None

    def to_payload(self) -> dict:
        return {"schema_version": SCHEMA_VERSION,
                "entries": self.entries}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_payload(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def from_payload(cls, payload: dict, where: str = "<payload>"):
        version = payload.get("schema_version")
        if version != SCHEMA_VERSION:
            raise StaleCacheError(
                f"autotune cache {where} has schema_version={version!r} "
                f"but this build writes {SCHEMA_VERSION} — re-tune "
                "(python -m repro.launch.autotune) instead of trusting "
                "stale winners")
        entries = payload.get("entries")
        if not isinstance(entries, dict):
            raise ValueError(f"autotune cache {where} has no 'entries' "
                             "mapping")
        for key, e in entries.items():
            blocks = e.get("blocks") if isinstance(e, dict) else None
            if not isinstance(blocks, dict) or not all(
                    isinstance(v, int) and v > 0 for v in blocks.values()):
                raise ValueError(
                    f"autotune cache {where} entry {key!r} has malformed "
                    f"blocks {blocks!r} (need a name -> positive-int map)")
        return cls(entries)

    @classmethod
    def load(cls, path: str):
        with open(path) as f:
            return cls.from_payload(json.load(f), where=path)


# --------------------------------------------------------------------------
# Scoped activation + the ops' lookup hook
# --------------------------------------------------------------------------

_STATE = threading.local()
_DEFAULT: dict = {"cache": None, "path": None}


@contextlib.contextmanager
def tuning(cache: AutotuneCache | None):
    """Activate ``cache`` for the ops' block resolution in this thread.

    ``None`` is a no-op scope (defaults everywhere) — the engine facade
    always enters this manager when ``config.autotune`` and lets a
    missing cache degrade silently to the hand-picked policy.
    """
    prev = getattr(_STATE, "cache", None)
    _STATE.cache = cache
    try:
        yield cache
    finally:
        _STATE.cache = prev


def active_cache() -> AutotuneCache | None:
    return getattr(_STATE, "cache", None)


def tuned_blocks(op: str, backend: str, *, n: int, k: int,
                 d: int) -> dict | None:
    """The active cache's blocks for this call site, or None.

    The public ops call this only when no explicit block override was
    passed, so overrides always win and the untuned path never pays a
    lookup.
    """
    cache = active_cache()
    if cache is None:
        return None
    return cache.lookup(op, backend, n=n, k=k, d=d)


def set_default_cache(cache: AutotuneCache | str | None):
    """Install the process default ``EngineConfig(autotune=True)`` uses
    (an :class:`AutotuneCache`, a path to load lazily, or None to clear
    back to the ``REPRO_AUTOTUNE_CACHE`` env lookup)."""
    if isinstance(cache, str):
        _DEFAULT.update(cache=None, path=cache)
    else:
        _DEFAULT.update(cache=cache, path=None)


def default_cache() -> AutotuneCache | None:
    """The process-default cache: ``set_default_cache``'s install wins,
    else the ``REPRO_AUTOTUNE_CACHE`` env path (when it exists), else
    None.  Loads lazily and memoises the loaded object."""
    if _DEFAULT["cache"] is not None:
        return _DEFAULT["cache"]
    path = _DEFAULT["path"] or os.environ.get("REPRO_AUTOTUNE_CACHE")
    if path and os.path.exists(path):
        _DEFAULT["cache"] = AutotuneCache.load(path)
        return _DEFAULT["cache"]
    return None


# --------------------------------------------------------------------------
# Candidate grids
# --------------------------------------------------------------------------

def default_blocks(op: str, backend: str, *, n: int, k: int, d: int) -> dict:
    """The hand-picked blocks the op resolves without any cache — the
    sweep's baseline candidate (kept bit-for-bit in sync with the ops'
    own no-override resolution)."""
    pol = layout.tile_policy(backend)
    if op == "flash_attention":
        return {"block_q": min(128, layout.round_up(n, pol.row_align)),
                "block_k": min(128, layout.round_up(k, pol.row_align))}
    return {"block_n": pol.block_for(n)}


def candidate_blocks(op: str, backend: str, *, n: int, k: int,
                     d: int) -> list[dict]:
    """Deterministic candidate list, default first, duplicates removed.

    Every candidate is passed through the backend's ``TilePolicy``
    alignment (``block_for`` / ``round_up``), so the grid can never
    propose a block the lowering rejects — including Triton's pow2 rule.
    The ``xla`` reference ignores block shapes entirely, so it gets the
    single default candidate (a sweep there would time one program five
    ways).
    """
    default = default_blocks(op, backend, n=n, k=k, d=d)
    if backend == "xla":
        return [default]
    pol = layout.tile_policy(backend)
    cands, seen = [], set()

    def add(blocks: dict):
        sig = tuple(sorted(blocks.items()))
        if sig not in seen:
            seen.add(sig)
            cands.append(blocks)

    add(default)
    if op == "flash_attention":
        for bq in FLASH_BLOCK_GRID:
            for bk in FLASH_BLOCK_GRID:
                add({"block_q": min(bq, layout.round_up(n, pol.row_align)),
                     "block_k": min(bk, layout.round_up(k, pol.row_align))})
    else:
        for b in ROW_BLOCK_GRID:
            add({"block_n": pol.block_for(n, b)})
    return cands


# --------------------------------------------------------------------------
# Sweep: operands, timing, analytic counts
# --------------------------------------------------------------------------

def _op_args(op: str, *, n: int, k: int, d: int, seed: int = 0) -> tuple:
    """Deterministic concrete operands for one sweep cell."""
    rng = np.random.default_rng(seed)
    if op == "kmeans_assign":
        return (jnp.asarray(rng.normal(0, 5, (n, d)).astype(np.float32)),
                jnp.asarray(rng.normal(0, 5, (k, d)).astype(np.float32)))
    if op == "gmm_estep":
        return (jnp.asarray(rng.normal(0, 5, (n, d)).astype(np.float32)),
                jnp.asarray(rng.normal(0, 2, (k, d)).astype(np.float32)),
                jnp.asarray((rng.random((k, d)) + 0.5).astype(np.float32)),
                jnp.asarray(np.log(np.full((k,), 1.0 / k,
                                           dtype=np.float32))))
    if op == "flash_attention":
        shape = (1, _FLASH_HEADS, n, d)
        kv = (1, _FLASH_HEADS, k, d)
        return tuple(jnp.asarray(rng.normal(0, 1, s).astype(np.float32))
                     for s in (shape, kv, kv))
    raise ValueError(f"unknown autotune op {op!r} "
                     f"(supported: {SUPPORTED_OPS})")


def make_op_call(op: str, backend: str, *, n: int, k: int, d: int,
                 seed: int = 0):
    """``blocks → zero-arg thunk`` running the public op at this cell.

    The thunks share one set of operand arrays, so candidate timings
    differ only by block shape.
    """
    args = _op_args(op, n=n, k=k, d=d, seed=seed)
    if op == "kmeans_assign":
        from repro.kernels.kmeans_assign.ops import kmeans_assign as fn
    elif op == "gmm_estep":
        from repro.kernels.gmm_estep.ops import gmm_estep as fn
    else:
        from repro.kernels.flash_attention.ops import flash_attention as fn

    def factory(blocks: dict):
        return lambda: fn(*args, backend=backend, **blocks)

    return factory


@functools.lru_cache(maxsize=None)
def analytic_cost(op: str, *, n: int, k: int, d: int):
    """FLOPs / HBM bytes of the op's math at this shape, from the Cost
    walker over the compiled ``xla`` reference — backend-independent
    analytic counts (the Pallas lowerings compute the same function)."""
    from repro.analysis.hlo_ir import analyze
    f32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    if op == "kmeans_assign":
        from repro.kernels.kmeans_assign.ops import kmeans_assign
        fn = functools.partial(kmeans_assign, backend="xla")
        args = (f32((n, d)), f32((k, d)))
    elif op == "gmm_estep":
        from repro.kernels.gmm_estep.ops import gmm_estep
        fn = functools.partial(gmm_estep, backend="xla")
        args = (f32((n, d)), f32((k, d)), f32((k, d)), f32((k,)))
    else:
        from repro.kernels.flash_attention.ops import flash_attention
        fn = functools.partial(flash_attention, backend="xla")
        q = f32((1, _FLASH_HEADS, n, d))
        kv = f32((1, _FLASH_HEADS, k, d))
        args = (q, kv, kv)
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return analyze(hlo)


def sweep_op(op: str, backend: str, *, n: int, k: int, d: int,
             reps: int = 5, warmup: int = 1, timer=None,
             call_factory=None, include_cost: bool = True,
             seed: int = 0) -> dict:
    """Time every candidate block shape for one (op, backend, shape) cell.

    Returns ``{"candidates": [{"blocks", "median_s"}, ...], "default",
    "winner", "flops", "bytes"}`` — candidates in deterministic order
    (default first), winner = argmin median (first on ties), so
    ``default.median_s / winner.median_s >= 1.0`` always holds within
    one sweep.  ``call_factory`` / ``timer`` are test hooks (fake ops,
    fake clock).
    """
    cands = candidate_blocks(op, backend, n=n, k=k, d=d)
    factory = call_factory if call_factory is not None \
        else make_op_call(op, backend, n=n, k=k, d=d, seed=seed)
    results = []
    for blocks in cands:
        t = time_callable(factory(blocks), reps=reps, warmup=warmup,
                          timer=timer)
        results.append({"blocks": dict(blocks), "median_s": t})
    winner = min(results, key=lambda r: r["median_s"])
    out = {"op": op, "backend": backend, "n": n, "k": k, "d": d,
           "candidates": results, "default": results[0], "winner": winner}
    if include_cost:
        cost = analytic_cost(op, n=n, k=k, d=d)
        out["flops"] = float(cost.flops)
        out["bytes"] = float(cost.bytes)
    return out


# --------------------------------------------------------------------------
# Roofline peaks (measured on this host, cached per process)
# --------------------------------------------------------------------------

# nominal fallback ceilings per device kind, used only when measurement
# is disabled; deliberately conservative
NOMINAL_PEAKS = {"cpu": (5.0e10, 2.0e10)}


@functools.lru_cache(maxsize=None)
def measure_peaks(kind: str | None = None) -> dict:
    """Achievable peak FLOP/s and HBM bytes/s on this host, via XLA.

    Peak compute = a large f32 matmul; peak bandwidth = a 64 MiB
    streaming add (reads + writes counted).  These are *achievable via
    XLA* peaks, not datasheet numbers — the right ceiling for kernels
    that themselves run through XLA/Pallas.  Median-of-3, cached per
    process.
    """
    kind = kind or device_kind()
    m = 1024
    a = jnp.ones((m, m), jnp.float32)
    b = jnp.ones((m, m), jnp.float32)
    mm = jax.jit(lambda a, b: a @ b)
    t_mm = time_callable(mm, a, b, reps=3, warmup=1)
    v = jnp.ones((64 * 1024 * 1024 // 4,), jnp.float32)
    add = jax.jit(lambda v: v + 1.0)
    t_bw = time_callable(add, v, reps=3, warmup=1)
    return {
        "device_kind": kind,
        "flops_per_s": 2.0 * m ** 3 / max(t_mm, 1e-12),
        "bytes_per_s": 2.0 * v.nbytes / max(t_bw, 1e-12),
        "method": "measured (f32 1024^3 matmul / 64MiB streaming add, "
                  "median-of-3)",
    }


def roofline_point(flops: float, bytes_: float, median_s: float,
                   peaks: dict) -> dict:
    """Achieved FLOP/s, arithmetic intensity, ceiling and the fraction of
    it this cell reaches — one row of the roofline table."""
    intensity = flops / max(bytes_, 1.0)
    achieved = flops / max(median_s, 1e-12)
    ceiling = min(peaks["flops_per_s"], intensity * peaks["bytes_per_s"])
    return {
        "achieved_flops_per_s": achieved,
        "arithmetic_intensity": intensity,
        "roofline_ceiling_flops_per_s": ceiling,
        "ceiling_fraction": achieved / max(ceiling, 1e-12),
        "bound": ("compute" if intensity * peaks["bytes_per_s"]
                  >= peaks["flops_per_s"] else "memory"),
    }


# --------------------------------------------------------------------------
# The end-to-end tuner (what launch/autotune.py drives)
# --------------------------------------------------------------------------

# importing an ops module is what registers its backends — the tuner
# drives ops by name, so it must force that import before asking the
# registry (a cycle-free lazy import: ops.py imports this module too)
_OP_MODULES = {
    "kmeans_assign": "repro.kernels.kmeans_assign.ops",
    "gmm_estep": "repro.kernels.gmm_estep.ops",
    "flash_attention": "repro.kernels.flash_attention.ops",
}


def _ensure_registered(op_name: str) -> None:
    mod = _OP_MODULES.get(op_name)
    if mod is not None:
        importlib.import_module(mod)


def available_backends(op_name: str) -> tuple[str, ...]:
    """Backends worth sweeping on this host: interpret + xla always,
    tpu/gpu only when the platform actually has the hardware."""
    _ensure_registered(op_name)
    reachable = {"interpret", "xla"}
    jb = jax.default_backend()
    if jb in ("tpu", "gpu"):
        reachable.add(jb)
    return tuple(b for b in dispatch.get_op(op_name).backends()
                 if b in reachable)


def tune(ops=None, backends=None, shapes=None, *, reps: int = 5,
         warmup: int = 1, timer=None, cache: AutotuneCache | None = None,
         call_factory=None, include_cost: bool = True,
         log=None) -> AutotuneCache:
    """Sweep the grid and collect winners into ``cache``.

    Cells already present in ``cache`` are skipped (cache-hit
    short-circuit — no re-timing), so an interrupted tune resumes and a
    merge run only fills holes.  ``shapes`` (``(n, k, d)`` triples)
    applies to every op; per-op defaults otherwise.
    """
    cache = cache if cache is not None else AutotuneCache()
    say = log or (lambda *_: None)
    for op in (ops or SUPPORTED_OPS):
        _ensure_registered(op)
        if op not in dispatch.registered_ops():
            say(f"# {op}: not registered, skipped")
            continue
        op_backends = backends or available_backends(op)
        for backend in op_backends:
            if backend not in dispatch.get_op(op).backends():
                say(f"# {op}/{backend}: backend not registered, skipped")
                continue
            for (n, k, d) in (shapes or DEFAULT_SHAPES[op]):
                if cache.lookup(op, backend, n=n, k=k, d=d) is not None:
                    say(f"# {op}/{backend} n{n} k{k} d{d}: cached, "
                        "skipped")
                    continue
                sw = sweep_op(op, backend, n=n, k=k, d=d, reps=reps,
                              warmup=warmup, timer=timer,
                              call_factory=call_factory,
                              include_cost=include_cost)
                meta = {
                    "median_s": sw["winner"]["median_s"],
                    "default_blocks": sw["default"]["blocks"],
                    "default_median_s": sw["default"]["median_s"],
                    "reps": reps,
                }
                if include_cost:
                    meta.update(flops=sw["flops"], bytes=sw["bytes"])
                cache.put(op, backend, n=n, k=k, d=d,
                          blocks=sw["winner"]["blocks"], **meta)
                say(f"# {op}/{backend} n{n} k{k} d{d}: "
                    f"{sw['winner']['blocks']} "
                    f"({sw['winner']['median_s'] * 1e3:.2f} ms, default "
                    f"{sw['default']['median_s'] * 1e3:.2f} ms, "
                    f"{len(sw['candidates'])} candidates)")
    return cache
