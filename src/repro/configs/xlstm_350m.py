"""xLSTM-350M: recurrent (7 mLSTM : 1 sLSTM per period), no separate FFN.

[arXiv:2405.04517; unverified] — 24L d1024 4H vocab 50304; d_ff=0 means the
projections live inside the blocks (mLSTM ×2.0, sLSTM post-FFN ×4/3).
O(1) state → runs long_500k.
"""
from .base import ArchConfig, register

_PERIOD = ("mlstm",) * 7 + ("slstm",)


def full() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m", family="ssm", n_layers=24,
        d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256, d_ff=0,
        vocab=50_304, period=_PERIOD, sub_quadratic=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m-reduced", family="ssm", n_layers=8,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=0,
        vocab=256, period=_PERIOD, sub_quadratic=True, remat="none")


register("xlstm-350m", full, reduced)
