"""Mistral-Nemo-12B: dense decoder, GQA, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf] — 40L d5120 32H kv8 head_dim 128
d_ff 14336 vocab 131072.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="mistral-nemo-12b", family="dense", n_layers=40,
        d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14_336,
        vocab=131_072, period=("attn",), rope_theta=1_000_000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mistral-nemo-12b-reduced", family="dense", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=256, period=("attn",), rope_theta=1_000_000.0, remat="none")


register("mistral-nemo-12b", full, reduced)
