"""HuBERT-XLarge: bidirectional audio encoder, masked-prediction objective.

[arXiv:2106.07447; unverified] — 48L d1280 16H kv16 head_dim 80 d_ff 5120
vocab 504 (cluster targets).  The conv feature extractor is a STUB per the
assignment: input_specs() supplies precomputed frame embeddings [B,S,1280].
Encoder-only → no decode shapes; RoPE disabled (conv positional stub).
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge", family="audio", n_layers=48,
        d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120,
        vocab=504, period=("attn",), encoder_only=True,
        embeddings_input=True, rope_theta=-1.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge-reduced", family="audio", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=32, period=("attn",), encoder_only=True,
        embeddings_input=True, rope_theta=-1.0, remat="none")


register("hubert-xlarge", full, reduced)
