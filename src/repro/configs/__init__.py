from .base import ArchConfig, MoEConfig, MambaConfig, get_config, list_archs
from .shapes import SHAPES, ShapeConfig, applicable, cells
