"""Jamba-v0.1-52B: hybrid Mamba+attention (1:7), MoE 16e top-2 every other layer.

[arXiv:2403.19887; hf] — 32L d4096 32H kv8 head_dim 128 d_ff 14336
vocab 65536; Mamba d_state 16, conv 4, expand 2; attention at period index 3;
no positional encoding (Mamba provides order).  Sub-quadratic: only 4/32
layers carry a KV cache → runs long_500k.
"""
from .base import ArchConfig, MoEConfig, MambaConfig, register

_PERIOD = ("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba")
_MOE_MASK = (False, True) * 4


def full() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32,
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14_336,
        vocab=65_536, period=_PERIOD,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14_336,
                      period_mask=_MOE_MASK),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        rope_theta=-1.0, sub_quadratic=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b-reduced", family="hybrid", n_layers=8,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=256, period=_PERIOD,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                      period_mask=_MOE_MASK),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        rope_theta=-1.0, sub_quadratic=True, remat="none")


register("jamba-v0.1-52b", full, reduced)
