"""Gemma-3-12B: dense decoder, 5:1 local(1024-window):global, qk-norm.

[hf:google/gemma-3; unverified] — 48L d3840 16H kv8 head_dim 256 d_ff 15360
vocab 262144; local RoPE θ=10k, global θ=1M.  Sub-quadratic enough for
long_500k: 40/48 layers have a 1024-token window (DESIGN §5).
"""
from .base import ArchConfig, register

_PERIOD = ("attn_local",) * 5 + ("attn_global",)


def full() -> ArchConfig:
    return ArchConfig(
        name="gemma3-12b", family="dense", n_layers=48,
        d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256, d_ff=15_360,
        vocab=262_144, period=_PERIOD, qk_norm=True,
        sliding_window=1024, rope_theta=10_000.0,
        rope_theta_global=1_000_000.0, sub_quadratic=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="gemma3-12b-reduced", family="dense", n_layers=6,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=256, period=_PERIOD, qk_norm=True,
        sliding_window=16, rope_theta=10_000.0,
        rope_theta_global=1_000_000.0, sub_quadratic=True, remat="none")


register("gemma3-12b", full, reduced)
