"""Qwen3-30B-A3B: MoE decoder, 128 experts top-8, qk-norm.

[hf:Qwen/Qwen3-30B-A3B; hf] — 48L d2048 32H kv4 d_ff_expert 768 vocab 151936.
"""
from .base import ArchConfig, MoEConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48,
        d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128, d_ff=0,
        vocab=151_936, period=("attn",), qk_norm=True,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
        rope_theta=1_000_000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b-reduced", family="moe", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=0,
        vocab=256, period=("attn",), qk_norm=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
        rope_theta=1_000_000.0, remat="none")


register("qwen3-moe-30b-a3b", full, reduced)
