"""Architecture config schema + registry.

Every assigned architecture is a frozen ``ArchConfig``; heterogeneous layer
stacks are expressed as a *period* — a tuple of layer kinds repeated
``n_layers / len(period)`` times (DESIGN.md §3: period-scanned stacks).
Layer kinds: "attn" | "attn_local" | "attn_global" | "cross" | "mamba" |
"mlstm" | "slstm".

``reduced()`` returns the same family at smoke-test scale (small width/depth,
few experts) — per the assignment, FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False
    # which period positions get MoE instead of dense MLP (None = all)
    period_mask: tuple[bool, ...] | None = None
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int                      # dense-MLP intermediate (0 = no FFN)
    vocab: int
    period: tuple[str, ...] = ("attn",)
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None    # gemma3: 1M for global layers
    sliding_window: int | None = None
    encoder_only: bool = False
    cross_attn_tokens: int = 0     # vlm: image tokens fed to cross layers
    cross_norm_kv: bool = True
    embeddings_input: bool = False  # audio/vlm stub frontend: inputs are [B,S,D]
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    use_flash_kernel: bool = False
    # xLSTM projection factors + chunkwise-parallel mLSTM (0 = sequential;
    # §Perf hillclimb #1 sets 128 — identical math, ≈L× less state traffic)
    xlstm_mlstm_proj: float = 2.0
    xlstm_slstm_proj: float = 4.0 / 3.0
    xlstm_chunk: int = 0
    # ring-buffer KV caches sized to the window for attn_local layers
    # (§Perf hillclimb #3; exact — window attention never looks further back)
    windowed_local_cache: bool = True
    # MoE dispatch groups (§Perf hillclimb #2): 0 = one global sort/scatter;
    # G > 1 = per-group local dispatch (align G with the DP shard count) so
    # token→expert routing becomes a buffer all-to-all instead of token
    # all-gathers.  Capacity is enforced per group (GShard-style).
    moe_dispatch_groups: int = 0
    # activation dtype for train/serve
    dtype: str = "bfloat16"
    # training-stability / loop knobs carried with the arch
    remat: str = "period"          # "none" | "period"
    sub_quadratic: bool = False    # eligible for long_500k decode

    def __post_init__(self):
        if self.n_layers % len(self.period):
            raise ValueError(f"{self.name}: n_layers {self.n_layers} not a "
                             f"multiple of period {len(self.period)}")

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def moe_at(self, period_pos: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.period_mask is None:
            return True
        return self.moe.period_mask[period_pos]

    def has_ffn_at(self, period_pos: int) -> bool:
        kind = self.period[period_pos]
        if kind in ("mlstm", "slstm"):
            return False             # xLSTM FFN lives inside the block
        return self.d_ff > 0 or self.moe_at(period_pos)

    # ---- analytics ----
    def param_count(self) -> int:
        """Exact parameter count from the initialiser structure (see zoo)."""
        from repro.models.model_zoo import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model_zoo import count_params
        return count_params(self, active_only=True)


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}
_REDUCED: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str, full: Callable[[], ArchConfig],
             reduced: Callable[[], ArchConfig]):
    _REGISTRY[name] = full
    _REDUCED[name] = reduced


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    _ensure_imported()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    _ensure_imported()
    return sorted(_REGISTRY)


def _ensure_imported():
    from repro.configs import archs  # noqa: F401  (registers on import)
