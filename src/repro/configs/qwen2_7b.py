"""Qwen2-7B: dense decoder, GQA, QKV bias.

[arXiv:2407.10671; hf] — 28L d3584 28H kv4 head_dim 128 d_ff 18944
vocab 152064.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b", family="dense", n_layers=28,
        d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18_944,
        vocab=152_064, period=("attn",), qkv_bias=True,
        rope_theta=1_000_000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b-reduced", family="dense", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=256, period=("attn",), qkv_bias=True,
        rope_theta=1_000_000.0, remat="none")


register("qwen2-7b", full, reduced)
