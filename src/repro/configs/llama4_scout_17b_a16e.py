"""Llama-4-Scout-17B-16E: MoE decoder, 16 routed experts top-1 + shared.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — 48L d5120 40H kv8
d_ff_expert 8192, vocab 202048.  Config assumptions in DESIGN.md §6
(head_dim 128, shared expert, RoPE on all layers).
"""
from .base import ArchConfig, MoEConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e", family="moe", n_layers=48,
        d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
        vocab=202_048, period=("attn",),
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                      shared_expert=True),
        rope_theta=500_000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e-reduced", family="moe", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=256, period=("attn",),
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128,
                      shared_expert=True),
        rope_theta=500_000.0, remat="none")


register("llama4-scout-17b-a16e", full, reduced)
