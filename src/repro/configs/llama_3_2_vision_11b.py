"""Llama-3.2-Vision-11B: text decoder with gated cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified] — 40L d4096 32H kv8
head_dim 128 d_ff 14336 vocab 128256; cross-attention every 5th layer
(8 of 40), tanh-gated; the vision tower is a STUB — input_specs() supplies
1601 precomputed patch embeddings per image.
"""
from .base import ArchConfig, register

_PERIOD = ("attn", "attn", "attn", "attn", "cross")


def full() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b", family="vlm", n_layers=40,
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14_336,
        vocab=128_256, period=_PERIOD, cross_attn_tokens=1601,
        rope_theta=500_000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b-reduced", family="vlm", n_layers=5,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=256, period=_PERIOD, cross_attn_tokens=16,
        rope_theta=500_000.0, remat="none")


register("llama-3.2-vision-11b", full, reduced)
