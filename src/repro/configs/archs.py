"""Import side-effect module: registers every assigned architecture."""
from . import (llama4_scout_17b_a16e, qwen3_moe_30b_a3b, gemma3_12b,
               mistral_nemo_12b, qwen2_7b, qwen3_8b, xlstm_350m,
               hubert_xlarge, jamba_v01_52b, llama_3_2_vision_11b)  # noqa: F401
