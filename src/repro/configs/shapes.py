"""Assigned input shapes (4 per architecture → 40 cells) + applicability."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN §5)"
    return True, ""


def cells(archs: list) -> list[tuple]:
    """All (arch_cfg, shape) cells with applicability flags."""
    out = []
    for cfg in archs:
        for shape in SHAPES.values():
            ok, why = applicable(cfg, shape)
            out.append((cfg, shape, ok, why))
    return out
