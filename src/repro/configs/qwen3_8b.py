"""Qwen3-8B: dense decoder, GQA, qk-norm.

[hf:Qwen/Qwen3-8B; hf] — 36L d4096 32H kv8 head_dim 128 d_ff 12288
vocab 151936.
"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b", family="dense", n_layers=36,
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=12_288,
        vocab=151_936, period=("attn",), qk_norm=True,
        rope_theta=1_000_000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b-reduced", family="dense", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=256, period=("attn",), qk_norm=True,
        rope_theta=1_000_000.0, remat="none")


register("qwen3-8b", full, reduced)
