"""AdamW + schedules + global-norm clipping — pure JAX, no optax.

Master weights are f32 (model code casts to the activation dtype at use),
moments are f32.  ``scale_by_adam_factored=False`` everywhere: at the target
scale the 2D-sharded moments fit comfortably (DESIGN.md §4 memory budget).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def lr_at(cfg: OptimizerConfig, step):
    """Linear warmup → cosine decay to end_lr_frac·peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.end_lr_frac + (1 - cfg.end_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


_NO_DECAY = ("norm", "scale", "gnorm", "bq", "bk", "bv", "dt_b", "bi", "bf",
             "conv_b", "xgate", "A_log", "b")


def _decay_mask(path) -> bool:
    last = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return last not in _NO_DECAY


def apply_updates(params, grads, state: OptState, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
