"""Fault-tolerant checkpointing: atomic npz + manifest, keep-last-N,
resharding restore (elastic scaling).

Layout:
    <dir>/step_000123/arrays.npz     — flat {path-key: np.ndarray}
    <dir>/step_000123/manifest.json  — step, keys, shapes, dtypes, extras
    <dir>/LATEST                     — committed step marker (atomic rename)

Writes go to ``<dir>/.tmp.<step>`` then ``os.replace`` — a crash mid-write
never corrupts the latest checkpoint (restart picks up the previous LATEST).
``restore`` device_puts each array with *target* shardings, so a checkpoint
saved on one mesh restores onto any other mesh/device count.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, tree, step: int, *, keep: int = 3,
         extras: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic commit
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template, *, step: int | None = None,
            shardings=None):
    """Restore into ``template``'s structure; device_put with ``shardings``
    (a matching pytree of NamedSharding) for cross-mesh elastic restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                     for p in kp) for kp, _ in leaves_p]
    missing = [k for k in keys if k not in arrays]
    if missing:
        raise KeyError(f"checkpoint {path} missing keys: {missing[:5]}…")
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(keys))
    out = []
    for k, (_, tmpl), sh in zip(keys, leaves_p, shard_leaves):
        arr = arrays[k].astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arrays[k]
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


def read_manifest(ckpt_dir: str, step: int | None = None) -> dict:
    if step is None:
        step = latest_step(ckpt_dir)
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)
