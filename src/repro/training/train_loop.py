"""Training loop: jit'd step factory + fault-tolerant host driver.

``make_train_step`` builds the donated, sharded step:
    state, metrics = step(state, batch)
with loss/grad in f32 master weights, optional int8 gradient compression
(error feedback carried in the state), AdamW, and the paper's long-tail
controller consuming the loss stream host-side (EarlyStopHook — EMA'd
Eq. 7 on the training objective, DESIGN.md §2 beyond-paper use).

``Trainer`` is the host loop: checkpoint-every-N with atomic commit +
restart-from-LATEST, straggler monitor, and failure injection for the
fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.distribution import compression
from repro.models import transformer
from . import checkpoint as ckpt_lib
from . import optimizer as opt_lib
from .straggler import StragglerMonitor


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.OptState
    ef: Any            # error-feedback buffers (None when compression off)
    rng: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_lib.OptimizerConfig = opt_lib.OptimizerConfig()
    compress_grads: bool = False    # int8 + error feedback
    aux_weight: float = 0.01
    microbatches: int = 1           # grad accumulation (activation-memory knob)


def init_state(key, cfg, train_cfg: TrainConfig) -> TrainState:
    params = transformer.init_lm(key, cfg)
    return TrainState(
        params=params,
        opt=opt_lib.init(params),
        ef=(compression.init_error_feedback(params)
            if train_cfg.compress_grads else None),
        rng=key,
    )


def make_train_step(cfg, train_cfg: TrainConfig) -> Callable:
    """Returns step(state, batch) → (state, metrics); jit it at the call
    site with the mesh-appropriate shardings (launch/train.py) or plainly
    on one device (examples/tests)."""

    def loss_fn(params, batch):
        return transformer.lm_loss(params, cfg, batch,
                                   aux_weight=train_cfg.aux_weight)

    def grads_of(params, batch):
        m = train_cfg.microbatches
        if m <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: scan over microbatches — peak activation
        # memory is one microbatch's remat footprint + the f32 grad buffer
        micro = jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

        def acc_step(carry, mb):
            g_acc, loss_acc, aux_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / m, g_acc, g)
            return (g_acc, loss_acc + loss / m,
                    aux_acc + metrics["moe_aux"] / m), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss, aux), _ = jax.lax.scan(
            acc_step, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
        metrics = {"loss": loss, "moe_aux": aux,
                   "perplexity_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
        return (loss, metrics), grads

    def step(state: TrainState, batch):
        (loss, metrics), grads = grads_of(state.params, batch)
        ef = state.ef
        if train_cfg.compress_grads:
            # Single-program form: numerically identical quant/dequant with
            # error feedback; the int8 *wire* path is the shard_map ring in
            # distribution/compression.py (exercised in tests/dryrun).
            grads, ef = compression.compress_with_feedback(
                grads, ef, lambda g: compression.fake_quantize_grads(g))
        new_params, new_opt, opt_metrics = opt_lib.apply_updates(
            state.params, grads, state.opt, train_cfg.opt)
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return TrainState(new_params, new_opt, ef, state.rng), metrics

    return step


# --------------------------------------------------------------------------
# Host driver
# --------------------------------------------------------------------------

class Trainer:
    """Fault-tolerant host loop.

    · checkpoints every ``ckpt_every`` steps (atomic, keep-last-N) and
      auto-resumes from LATEST on construction;
    · optional ``EarlyStopHook`` (the paper's controller) halts on the
      loss-change-rate threshold;
    · ``fail_at`` injects a crash (tests restart-recovery);
    · per-step wall time feeds the straggler monitor.
    """

    def __init__(self, cfg, train_cfg: TrainConfig, data_iter, *,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 keep: int = 3, earlystop=None, seed: int = 0,
                 jit_step: bool = True, fail_at: int | None = None):
        self.cfg = cfg
        self.train_cfg = train_cfg
        self.data_iter = data_iter
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.earlystop = earlystop
        self.fail_at = fail_at
        self.monitor = StragglerMonitor()
        self.metrics_log: list[dict] = []

        step_fn = make_train_step(cfg, train_cfg)
        self._step_fn = jax.jit(step_fn, donate_argnums=0) if jit_step else step_fn

        key = jax.random.PRNGKey(seed)
        self.state = init_state(key, cfg, train_cfg)
        self.step = 0
        if ckpt_dir is not None and ckpt_lib.latest_step(ckpt_dir) is not None:
            self.state, self.step = ckpt_lib.restore(ckpt_dir, self.state)
            self.step = int(self.step)

    def run(self, num_steps: int) -> dict:
        stopped_early = False
        while self.step < num_steps:
            batch = next(self.data_iter)
            self.monitor.start()
            if self.fail_at is not None and self.step == self.fail_at:
                raise RuntimeError(f"injected failure at step {self.step}")
            self.state, metrics = self._step_fn(self.state, batch)
            loss = float(metrics["loss"])
            self.monitor.stop()
            self.step += 1
            self.metrics_log.append({"step": self.step, "loss": loss})
            if self.ckpt_dir and self.step % self.ckpt_every == 0:
                ckpt_lib.save(self.ckpt_dir, self.state, self.step,
                              keep=self.keep)
            if self.earlystop is not None and self.earlystop.update(loss):
                stopped_early = True
                break
        if self.ckpt_dir:
            ckpt_lib.save(self.ckpt_dir, self.state, self.step, keep=self.keep)
        return {
            "final_step": self.step,
            "stopped_early": stopped_early,
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "straggler": self.monitor.report(),
        }
