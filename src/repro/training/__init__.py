from . import optimizer, checkpoint, straggler
from .train_loop import Trainer, TrainConfig, TrainState, make_train_step, init_state
from .optimizer import OptimizerConfig
