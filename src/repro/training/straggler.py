"""Host-side straggler watchdog (DESIGN.md §4).

At thousand-node scale a single slow host throttles every synchronous step.
The monitor keeps a rolling window of per-step wall times; a step is flagged
when it exceeds ``factor`` × the window median (p95-style heuristics are too
jumpy at small windows).  Flags feed the launcher's retry/requeue policy; in
this repo they surface in train logs + the Trainer's report.
"""
from __future__ import annotations

import time


class StragglerMonitor:
    def __init__(self, window: int = 50, factor: float = 2.0,
                 grace_steps: int = 5):
        self.window = window
        self.factor = factor
        self.grace_steps = grace_steps
        self.times: list[float] = []
        self.flagged: list[tuple[int, float, float]] = []  # (step, t, median)
        self._t0 = None
        self._step = 0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record one step; True if this step is flagged as a straggler."""
        dt = time.monotonic() - self._t0
        self._step += 1
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = sorted(hist)[len(hist) // 2]
        is_straggler = (self._step > self.grace_steps
                        and len(hist) >= 10 and dt > self.factor * med)
        if is_straggler:
            self.flagged.append((self._step, dt, med))
        return is_straggler

    def report(self) -> dict:
        if not self.times:
            return {"steps": 0}
        hist = sorted(self.times)
        n = len(hist)
        return {
            "steps": n,
            "median_s": hist[n // 2],
            "p95_s": hist[min(n - 1, int(0.95 * n))],
            "flagged": len(self.flagged),
        }
