"""Random-sampling group strategy + k-fold split (paper §4, §5.2).

The paper partitions the data set into n groups of equal size by uniform
random sampling ("each subject … has the same probability of being chosen"),
then 10-fold cross-validates groups into training/validation sets.  Image
data sets (SpaceNet) treat each image as one group.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class GroupedData:
    groups: np.ndarray        # [n_groups, group_size, d]
    train_idx: np.ndarray     # indices into groups
    val_idx: np.ndarray

    @property
    def train_groups(self):
        return self.groups[self.train_idx]

    @property
    def val_groups(self):
        return self.groups[self.val_idx]


def random_groups(data: np.ndarray, group_size: int, *, seed: int = 0,
                  max_groups: int | None = None) -> np.ndarray:
    """Shuffle and split into ⌊n/group_size⌋ equal groups (drop remainder).

    Paper guidance (§5.2): group_size ≥ 10,000 and ≥ 50 groups works best;
    callers assert that when running the paper-faithful experiments.
    """
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    n_groups = n // group_size
    if max_groups is not None:
        n_groups = min(n_groups, max_groups)
    perm = rng.permutation(n)[: n_groups * group_size]
    return data[perm].reshape(n_groups, group_size, data.shape[-1])


def kfold_split(n_groups: int, fold: int = 0, n_folds: int = 10, *,
                seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """10-fold CV over *groups* (paper §5.2). Returns (train_idx, val_idx)."""
    if not 0 <= fold < n_folds:
        raise ValueError(f"fold {fold} out of range for {n_folds} folds")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_groups)
    folds = np.array_split(perm, n_folds)
    val = folds[fold]
    train = np.concatenate([f for i, f in enumerate(folds) if i != fold])
    return np.sort(train), np.sort(val)


def make_grouped(data: np.ndarray, group_size: int, *, fold: int = 0,
                 n_folds: int = 10, seed: int = 0,
                 max_groups: int | None = None) -> GroupedData:
    groups = random_groups(data, group_size, seed=seed, max_groups=max_groups)
    train, val = kfold_split(groups.shape[0], fold, n_folds, seed=seed + 1)
    return GroupedData(groups=groups, train_idx=train, val_idx=val)
