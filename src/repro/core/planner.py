"""Cost-aware provisioning planner — the decision layer over Eq. 6/9/10.

The paper proves the long tail is not worth paying for (99% accuracy at
47.71–71.14% of the k-means full-convergence cost, 16.69–32.04% for EM) but
leaves the *decision* to the reader: which engine configuration, on how many
instances, at which market price, actually minimises the bill for a target
accuracy under a deadline?  This module closes that loop — the
D-SPACE4Cloud direction in PAPERS.md (performance-model-driven capacity
planning) stacked on the paper's own h(r) model, with DV-ARPA's
pricing-aware provisioning as the spot-market extension:

  · **iterations** come from the fitted mode-matched h(r) model
    (``repro.core.longtail_train``): h* = f(r*) per candidate mode, pushed
    through an :class:`IterationModel` — a geometric-decay fit of the
    harvested Eq. 7 h trajectory (h_i ≈ h₀·ρⁱ), with the paired-h noise
    floor recorded so thresholds the mode cannot certify predict
    ``max_iters`` instead of a fantasy early stop;

  · **wall time** comes from measured per-iteration throughput
    interpolated off the committed ``BENCH_*.json`` trajectory
    (minibatch_shard, kernel_backends, sharded_overlap, roofline) — see
    :class:`ThroughputModel` for the (N, devices) interpolation contract;

  · **dollars** come from the extended cost model
    (``repro.core.cost_model``): on-demand + spot price pairs, with spot
    walls inflated by the expected-restart model before both the deadline
    check and the Eq. 6 bill.

``plan()`` enumerates the candidate space (mode × devices × compression ×
prefetch × instance × pricing), drops candidates that miss the deadline,
and returns a :class:`PlanReport` — the cheapest feasible
:class:`CandidatePlan` (directly convertible to ``EngineConfig`` kwargs),
the runner-up table, and the full-convergence reference the paper's
cost-fraction claim is measured against.  ``repro.launch.plan`` is the CLI;
``--validate`` executes the chosen plan through the real fit drivers and
``BENCH_plan.json`` gates predicted-vs-actual in CI.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import math
import os
from typing import Sequence

import numpy as np

from .cost_model import PriceTable, candidate_cost_usd, priced_wall_s


class PlanError(ValueError):
    """The planner cannot emit a plan; the message names the binding
    constraint (empty price table, deadline infeasibility with the fastest
    candidate's wall, or missing throughput coverage)."""


# --------------------------------------------------------------------------
# Iteration prediction: geometric tail fit of the harvested h trajectory
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IterationModel:
    """Predicted stop iteration as a function of the Eq. 7 threshold h*.

    Fit from harvested traces (the same ones the h(r) regression pools):
    the long tail is near-geometric, ``h_i ≈ h0 · rho^i``, so the first
    iteration with h ≤ h* is ``log(h*/h0) / log(rho)``.  Two guard rails:

      · ``h_floor`` — the observed noise floor of the h signal (median of
        each trace's final quartile).  Minibatch paired h plateaus at a
        positive floor; an h* at or below it never fires and the fit runs
        to ``max_iters`` (exactly the behaviour
        ``BENCH_longtail_matched.json`` records at r* = 0.99), so the
        predictor says so instead of extrapolating the decay through the
        plateau.
      · ``n_full`` — the observed full-convergence iteration count (mean
        across traces), the paper's Time_full denominator in iterations.
    """
    h0: float
    rho: float
    h_floor: float
    n_full: int
    n_traces: int = 1

    @classmethod
    def from_traces(cls, hs: Sequence[np.ndarray]) -> "IterationModel":
        """Least-squares log-linear fit pooled over iteration-ordered h
        sequences (finite, positive entries only)."""
        xs, ys, floors, lengths = [], [], [], []
        for h in hs:
            h = np.asarray(h, np.float64)
            valid = np.isfinite(h) & (h > 0)
            idx = np.nonzero(valid)[0]
            if idx.size == 0:
                continue
            lengths.append(h.shape[0])
            xs.append(idx.astype(np.float64))
            ys.append(np.log(h[idx]))
            tail = h[idx][-max(1, idx.size // 4):]
            floors.append(float(np.median(tail)))
        if not xs:
            raise PlanError(
                "IterationModel.from_traces: no finite positive h values "
                "in any trace — harvest traces with EngineConfig(trace="
                "True) before planning")
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        if x.size >= 2 and np.ptp(x) > 0:
            slope, intercept = np.polyfit(x, y, 1)
        else:
            slope, intercept = 0.0, float(y.mean())
        rho = float(np.exp(min(slope, 0.0)))          # decay only
        return cls(h0=float(np.exp(intercept)), rho=min(rho, 1.0 - 1e-9),
                   h_floor=float(np.median(floors)),
                   n_full=int(math.ceil(float(np.mean(lengths)))),
                   n_traces=len(xs))

    def iters_to(self, h_star: float, max_iters: int,
                 patience: int = 1) -> int:
        """First iteration with h ≤ h*, plus the patience window the
        engine's stop predicate requires; clamped to [1, max_iters]."""
        if h_star <= 0 or h_star <= self.h_floor:
            # below the signal's noise floor the predicate never fires
            return max_iters
        if h_star >= self.h0:
            n = 1
        else:
            n = int(math.ceil(math.log(h_star / self.h0)
                              / math.log(self.rho)))
        return max(1, min(n + (patience - 1), max_iters))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# Throughput: per-iteration seconds interpolated from committed BENCH_*.json
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ThroughputPoint:
    """One measured cell: seconds per iteration at a known touched-point
    count (N × the mode's per-iteration touch fraction — 2·B/C under the
    paired minibatch stop, 1 for a full sweep)."""
    source: str
    mode: str                       # "full" | "minibatch"
    backend: str | None             # kernel backend; None = jnp path
    compression: str                # "none" | "int8_ef"
    devices: int
    touched_points: float
    s_per_iter: float


def _repo_root() -> str:
    return os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))


def load_bench_points(bench_dir: str | None = None) -> list[ThroughputPoint]:
    """Harvest throughput points from every committed ``BENCH_*.json`` the
    planner understands (minibatch_shard, kernel_backends, sharded_overlap;
    roofline rows ride separately via :func:`load_roofline_points`).
    Missing files are skipped — the planner errors only when a *query*
    finds no coverage."""
    root = bench_dir or _repo_root()
    pts: list[ThroughputPoint] = []

    def _load(name):
        path = os.path.join(root, name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    mb = _load("BENCH_minibatch_shard.json")
    if mb:
        touched = 2.0 * mb["n"] * mb["batch_chunks"] / mb["chunks"]
        for r in mb["rows"]:
            pts.append(ThroughputPoint(
                source="minibatch_shard", mode="minibatch", backend=None,
                compression="none", devices=int(r["devices"]),
                touched_points=touched,
                s_per_iter=r["wall_s_fit"] / max(r["iters"], 1)))

    kb = _load("BENCH_kernel_backends.json")
    if kb:
        frac = {"full": 1.0,
                "minibatch": 2.0 * kb["batch_chunks"] / kb["chunks"]}
        for r in kb["rows"]:
            pts.append(ThroughputPoint(
                source="kernel_backends", mode=r["mode"],
                backend=r["backend"], compression="none",
                devices=int(r["devices"]),
                touched_points=kb["n"] * frac[r["mode"]],
                s_per_iter=r["wall_s_fit"] / max(r["iters"], 1)))

    ov = _load("BENCH_sharded_overlap.json")
    if ov:
        touched = 2.0 * ov["n"] * ov["batch_chunks"] / ov["chunks"]
        for r in ov["rows"]:
            if r["leg"] != "sync":       # overlap wall is advisory (flags)
                continue
            pts.append(ThroughputPoint(
                source="sharded_overlap", mode="minibatch", backend=None,
                compression=r["compression"], devices=int(r["devices"]),
                touched_points=touched, s_per_iter=r["s_per_sweep"]))
    return pts


def load_roofline_points(bench_dir: str | None = None) -> list[dict]:
    """Per-op achieved FLOP/s rows from ``BENCH_roofline.json`` — the
    fallback when no engine-level bench covers a (mode, backend) cell
    (e.g. a tpu/gpu backend tuned on real hardware)."""
    root = bench_dir or _repo_root()
    path = os.path.join(root, "BENCH_roofline.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f).get("rows", [])


def _interp(x: float, xs: Sequence[float], ys: Sequence[float]) -> float:
    """Piecewise-linear interpolation with end clamping (the conservative
    choice off-grid: never extrapolate a trend past the measured range)."""
    order = np.argsort(xs)
    xs = np.asarray(xs, np.float64)[order]
    ys = np.asarray(ys, np.float64)[order]
    return float(np.interp(x, xs, ys))


@dataclasses.dataclass(frozen=True)
class ThroughputModel:
    """Seconds/iteration predictor over (touched points, devices, mode,
    backend, compression), interpolated from measured bench points.

    Interpolation contract (tested off-grid in ``tests/test_planner.py``):

      · **N axis** — within one (mode, backend, compression, devices)
        group, s/iter is piecewise-linear in touched points between the
        measured sizes; below the smallest measurement it scales by the
        smallest measurement's per-point rate (linear through the origin —
        per-iteration dispatch overhead is not separable from one point,
        so small-N walls are under-predicted; the validation tolerance
        band owns that); above the largest it scales by the largest
        measurement's per-point rate.
      · **devices axis** — s/iter evaluated at each measured device count,
        then piecewise-linear in log₂(devices), clamped at the grid ends.

    A query with no measured points for its (mode, backend, compression)
    triple falls back to the roofline table's per-op FLOP/s for that
    backend when available, else raises :class:`PlanError` naming the
    uncovered cell.
    """
    points: tuple[ThroughputPoint, ...]
    roofline: tuple[dict, ...] = ()

    @classmethod
    def from_bench_dir(cls, bench_dir: str | None = None):
        return cls(points=tuple(load_bench_points(bench_dir)),
                   roofline=tuple(load_roofline_points(bench_dir)))

    def _group(self, mode, backend, compression):
        sel = [p for p in self.points
               if p.mode == mode and p.backend == backend
               and p.compression == compression]
        if not sel and backend is None:
            # the jnp sweep path has no dedicated full-mode bench; the
            # "xla" kernel backend is the jitted reference implementation
            # (same compiler, same arithmetic), so its points stand in
            sel = [p for p in self.points
                   if p.mode == mode and p.backend == "xla"
                   and p.compression == compression]
        if not sel and compression == "int8_ef":
            # int8 coverage exists only for the jnp minibatch path today;
            # other cells reuse the uncompressed measurement (the ring
            # changes wire bytes, not flops — wall impact is advisory)
            sel = [p for p in self.points
                   if p.mode == mode and p.backend == backend
                   and p.compression == "none"]
        return sel

    def _s_iter_at_devices(self, pts, touched):
        by_dev: dict[int, list[float]] = {}
        for p in pts:
            if touched <= p.touched_points:
                samples = sorted((q.touched_points, q.s_per_iter)
                                 for q in pts if q.devices == p.devices)
                xs = [0.0] + [s[0] for s in samples]
                ys = [0.0] + [s[1] for s in samples]
                val = _interp(touched, xs, ys)
            else:
                top = max((q for q in pts if q.devices == p.devices),
                          key=lambda q: q.touched_points)
                val = top.s_per_iter * touched / top.touched_points
            by_dev.setdefault(p.devices, []).append(val)
        return {d: float(np.mean(v)) for d, v in by_dev.items()}

    def seconds_per_iter(self, touched_points: float, devices: int, *,
                         mode: str, backend: str | None,
                         compression: str = "none") -> float:
        pts = self._group(mode, backend, compression)
        if pts:
            per_dev = self._s_iter_at_devices(pts, touched_points)
            devs = sorted(per_dev)
            return _interp(math.log2(max(devices, 1)),
                           [math.log2(d) for d in devs],
                           [per_dev[d] for d in devs])
        return self._roofline_fallback(touched_points, devices, mode,
                                       backend, compression)

    def _roofline_fallback(self, touched, devices, mode, backend,
                           compression):
        rows = [r for r in self.roofline
                if r["op"] == "kmeans_assign" and r["backend"] == backend]
        if not rows:
            raise PlanError(
                f"no throughput coverage for (mode={mode!r}, "
                f"backend={backend!r}, compression={compression!r}): not "
                "measured in BENCH_minibatch_shard / BENCH_kernel_backends"
                " / BENCH_sharded_overlap, and BENCH_roofline has no "
                f"{backend!r} rows — run `python -m benchmarks.run --only "
                "kernel_backends` (or benchmarks.roofline) on a host with "
                "that backend")
        # sweep FLOPs ≈ the assign op's per-point FLOP rate × touched
        # points, at the backend's best achieved FLOP/s; device scaling is
        # ideal-linear here (no measured collective overhead to interpolate)
        best = max(rows, key=lambda r: r["achieved_flops_per_s"])
        flops_per_point = best["flops"] / best["n"]
        s = touched * flops_per_point / best["achieved_flops_per_s"]
        return s / max(devices, 1)

    def coverage(self) -> list[str]:
        return sorted({f"{p.mode}/{p.backend or 'jnp'}/{p.compression}"
                       f"@d{p.devices}" for p in self.points})


# --------------------------------------------------------------------------
# Candidate space + search
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """What to provision for: problem size, accuracy target, deadline and
    market, plus the engine knobs the search is allowed to move."""
    n: int
    d: int
    k: int
    target_r: float
    deadline_s: float
    prices: PriceTable
    max_iters: int = 400
    chunks: int = 64
    batch_chunks: int = 16
    decay: float = 0.95
    patience: int = 3
    device_grid: tuple = (1, 2, 4, 8)
    modes: tuple = ("full", "minibatch")
    compressions: tuple = ("none", "int8_ef")
    prefetch_options: tuple = (False,)
    backend: str | None = None          # kernel backend; None = jnp sweeps
    # one-off h(r) training time, recorded for the Eq. 9 ledger; NOT added
    # to per-task candidate costs (the paper amortises it over the task
    # stream — §5.4 calls it negligible at fleet scale)
    train_time_s: float = 0.0
    restart_overhead_s: float = 60.0
    checkpoint_interval_s: float | None = None

    def __post_init__(self):
        if not 0.0 < self.target_r <= 1.0:
            raise ValueError(f"target_r must be in (0, 1], got "
                             f"{self.target_r}")
        if self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got "
                             f"{self.deadline_s}")


@dataclasses.dataclass(frozen=True)
class CandidatePlan:
    """One priced point of the configuration space.  ``engine_kwargs()``
    rebuilds the exact ``EngineConfig`` the prediction was made for."""
    mode: str
    devices: int
    instance: str
    pricing: str                    # "on_demand" | "spot"
    backend: str | None
    stats_compression: str
    prefetch: bool
    chunks: int
    batch_chunks: int
    decay: float
    h_star: float
    predicted_iters: int
    predicted_wall_s: float         # raw predicted compute wall
    billed_wall_s: float            # spot-inflated wall (deadline + Eq. 6)
    predicted_cost_usd: float
    feasible: bool
    binding_constraint: str | None = None
    at_noise_floor: bool = False    # h* ≤ the mode's h noise floor

    def engine_kwargs(self) -> dict:
        kw = dict(mode=self.mode, chunks=self.chunks,
                  h_star=self.h_star,
                  stats_compression=self.stats_compression,
                  prefetch=self.prefetch)
        if self.mode == "minibatch":
            kw.update(batch_chunks=self.batch_chunks, decay=self.decay)
        if self.backend is not None:
            kw.update(use_kernel=True, kernel_backend=self.backend)
        return kw

    def describe(self) -> str:
        bk = self.backend or "jnp"
        return (f"{self.mode}/{bk}/d{self.devices}/{self.instance}/"
                f"{self.pricing}"
                + (f"/{self.stats_compression}"
                   if self.stats_compression != "none" else "")
                + ("/prefetch" if self.prefetch else ""))


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """The planner's deliverable: the cheapest feasible candidate, the
    runner-up table, and the full-convergence reference that turns the
    paper's cost-fraction claim into a number for THIS problem."""
    spec: dict                      # PlanSpec minus the price table object
    h_star_by_mode: dict
    chosen: CandidatePlan
    candidates: tuple[CandidatePlan, ...]
    full_reference: dict            # iters / wall_s / cost_usd / where
    cost_fraction: float            # chosen cost / full-convergence cost

    def to_json(self) -> str:
        d = {
            "spec": self.spec,
            "h_star_by_mode": self.h_star_by_mode,
            "chosen": dataclasses.asdict(self.chosen),
            "candidates": [dataclasses.asdict(c) for c in self.candidates],
            "full_reference": self.full_reference,
            "cost_fraction": self.cost_fraction,
        }
        return json.dumps(d, indent=1)

    @staticmethod
    def from_json(s: str) -> "PlanReport":
        d = json.loads(s)
        return PlanReport(
            spec=d["spec"], h_star_by_mode=d["h_star_by_mode"],
            chosen=CandidatePlan(**d["chosen"]),
            candidates=tuple(CandidatePlan(**c) for c in d["candidates"]),
            full_reference=d["full_reference"],
            cost_fraction=d["cost_fraction"])

    def table(self, limit: int = 12) -> str:
        """Human-readable runner-up table (the CLI prints this)."""
        hdr = (f"{'candidate':44s} {'iters':>6s} {'wall_s':>9s} "
               f"{'billed_s':>9s} {'cost_usd':>12s} feasible")
        lines = [hdr, "-" * len(hdr)]
        for c in self.candidates[:limit]:
            mark = " <== chosen" if c == self.chosen else ""
            lines.append(
                f"{c.describe():44s} {c.predicted_iters:6d} "
                f"{c.predicted_wall_s:9.3f} {c.billed_wall_s:9.3f} "
                f"{c.predicted_cost_usd:12.8f} "
                f"{'yes' if c.feasible else 'no ':3s}{mark}")
        return "\n".join(lines)


def _touched_points(spec: PlanSpec, mode: str) -> float:
    """Points touched per iteration: N for a full sweep, 2·N·B/C for the
    paired minibatch stop (the pairing's second pass is real compute)."""
    if mode == "minibatch":
        return 2.0 * spec.n * spec.batch_chunks / spec.chunks
    return float(spec.n)


def plan(spec: PlanSpec, *, models: dict, iteration_models: dict,
         throughput: ThroughputModel) -> PlanReport:
    """Search the candidate space and return the cheapest feasible plan.

    ``models``: mode → fitted ``LongTailModel`` (h* = f(r*) per mode —
    mode-matched, per ``BENCH_longtail_matched.json``'s case for never
    transferring thresholds across regimes).  ``iteration_models``: mode →
    :class:`IterationModel` fitted from the same harvest's h traces.
    Raises :class:`PlanError` naming the binding constraint when no
    candidate is feasible.
    """
    if len(spec.prices) == 0:
        raise PlanError(
            "price table is empty — nothing to provision; pass at least "
            "one Price (CLI: --prices table.json, or omit --prices for "
            "PriceTable.default())")
    missing = [m for m in spec.modes
               if m not in models or m not in iteration_models]
    if missing:
        raise PlanError(
            f"no fitted h(r)/iteration model for mode(s) {missing} — "
            "harvest and fit them first (repro.launch.plan does this "
            "from the dataset groups)")

    h_star_by_mode = {m: float(models[m].threshold_for(spec.target_r))
                      for m in spec.modes}
    candidates: list[CandidatePlan] = []
    for mode in spec.modes:
        im: IterationModel = iteration_models[mode]
        h_star = h_star_by_mode[mode]
        iters = im.iters_to(h_star, spec.max_iters, patience=spec.patience)
        at_floor = h_star <= im.h_floor
        touched = _touched_points(spec, mode)
        comps = [c for c in spec.compressions
                 if not (c == "int8_ef" and mode == "full")]
        for devices in spec.device_grid:
            for comp in comps:
                if comp == "int8_ef" and devices < 2:
                    continue        # a 1-device ring is the identity
                for prefetch in spec.prefetch_options:
                    s_iter = throughput.seconds_per_iter(
                        touched, devices, mode=mode, backend=spec.backend,
                        compression=comp)
                    wall = iters * s_iter
                    for price in spec.prices.prices:
                        for pricing in price.pricings:
                            billed = priced_wall_s(
                                wall, price, devices, pricing,
                                restart_overhead_s=spec.restart_overhead_s,
                                checkpoint_interval_s=
                                spec.checkpoint_interval_s)
                            cost = candidate_cost_usd(
                                wall, price, devices, pricing,
                                restart_overhead_s=spec.restart_overhead_s,
                                checkpoint_interval_s=
                                spec.checkpoint_interval_s)
                            feasible = billed <= spec.deadline_s
                            candidates.append(CandidatePlan(
                                mode=mode, devices=devices,
                                instance=price.name, pricing=pricing,
                                backend=spec.backend,
                                stats_compression=comp, prefetch=prefetch,
                                chunks=spec.chunks,
                                batch_chunks=(spec.batch_chunks
                                              if mode == "minibatch"
                                              else 0),
                                decay=(spec.decay if mode == "minibatch"
                                       else 1.0),
                                h_star=h_star, predicted_iters=iters,
                                predicted_wall_s=wall,
                                billed_wall_s=billed,
                                predicted_cost_usd=cost,
                                feasible=feasible,
                                binding_constraint=(None if feasible
                                                    else "deadline_s"),
                                at_noise_floor=at_floor))

    candidates.sort(key=lambda c: (not c.feasible, c.predicted_cost_usd))
    feasible = [c for c in candidates if c.feasible]
    if not feasible:
        fastest = min(candidates, key=lambda c: c.billed_wall_s)
        raise PlanError(
            f"deadline_s={spec.deadline_s} is infeasible: the fastest "
            f"candidate ({fastest.describe()}) still needs "
            f"{fastest.billed_wall_s:.3f}s billed wall "
            f"({fastest.predicted_iters} iters × "
            f"{fastest.predicted_wall_s / fastest.predicted_iters:.4f}"
            "s/iter) — the binding constraint is the deadline; raise it "
            f"above {fastest.billed_wall_s:.3f}s or widen the search "
            "space (devices/backends)")
    chosen = feasible[0]

    # the paper's cost-fraction denominator: the SAME placement (instance,
    # devices, pricing) run full-batch to full convergence — the Time_full
    # baseline of Eq. 10, here in predicted dollars
    im_full: IterationModel = iteration_models.get(
        "full", iteration_models[chosen.mode])
    full_iters = im_full.n_full
    full_s_iter = throughput.seconds_per_iter(
        float(spec.n), chosen.devices, mode="full", backend=spec.backend,
        compression="none")
    full_wall = full_iters * full_s_iter
    price = spec.prices.get(chosen.instance)
    full_cost = candidate_cost_usd(
        full_wall, price, chosen.devices, chosen.pricing,
        restart_overhead_s=spec.restart_overhead_s,
        checkpoint_interval_s=spec.checkpoint_interval_s)
    full_reference = {
        "iters": full_iters, "wall_s": full_wall, "cost_usd": full_cost,
        "instance": chosen.instance, "devices": chosen.devices,
        "pricing": chosen.pricing,
    }

    spec_d = dataclasses.asdict(spec)
    spec_d["prices"] = [p.name for p in spec.prices.prices]
    return PlanReport(
        spec=spec_d, h_star_by_mode=h_star_by_mode, chosen=chosen,
        candidates=tuple(candidates),
        full_reference=full_reference,
        cost_fraction=(chosen.predicted_cost_usd / full_cost
                       if full_cost > 0 else float("inf")))


def bench_files(bench_dir: str | None = None) -> list[str]:
    """The committed BENCH_*.json artifacts visible to the planner."""
    root = bench_dir or _repo_root()
    return sorted(os.path.basename(p)
                  for p in glob.glob(os.path.join(root, "BENCH_*.json")))
