"""Regression between objective change-rate h and clustering accuracy r (Eq. 8).

The paper fits  h = β₀ + β₁·r + β₂·r²  on (r_i, h_i) pairs harvested from the
training groups, after comparing regression families by SSE / R² / adj-R² /
RMSE and finding the quadratic polynomial best in most cases.  We implement
the full family comparison so the selection claim itself is reproducible:

    linear, quadratic, cubic        — polynomial least squares
    exponential  h = a·exp(b·r)     — log-space linear fit (h > 0 required)
    lasso-quadratic                 — L1 on the quadratic basis (coord. descent)

Fitting is closed-form / deterministic JAX (no sklearn), so the same code
runs on-device inside the distributed pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

FAMILIES = ("linear", "quadratic", "cubic", "exponential", "lasso_quadratic",
            "log_quadratic")


@dataclasses.dataclass(frozen=True)
class FitMetrics:
    sse: float
    rmse: float
    r2: float
    adj_r2: float


@dataclasses.dataclass(frozen=True)
class RegressionModel:
    """A fitted h(r) model.  ``coeffs`` meaning depends on family."""
    family: str
    coeffs: tuple[float, ...]
    metrics: FitMetrics

    def predict(self, r):
        r = jnp.asarray(r)
        c = jnp.asarray(self.coeffs)
        if self.family in ("linear", "quadratic", "cubic", "lasso_quadratic"):
            # coeffs = (β₀, β₁, …) low-to-high degree
            powers = jnp.stack([r ** p for p in range(len(self.coeffs))], axis=-1)
            return powers @ c
        if self.family == "exponential":
            a, b = self.coeffs
            return a * jnp.exp(b * r)
        if self.family == "log_quadratic":
            # log h = β₀ + β₁ r + β₂ r² — handles h spanning many decades
            # (EM tails); beyond-paper family, sanctioned by §5.5.
            b0, b1, b2 = self.coeffs
            return jnp.exp(b0 + b1 * r + b2 * r * r)
        raise ValueError(f"unknown family {self.family}")

    def threshold_for(self, desired_accuracy: float, floor: float = 1e-12) -> float:
        """h* = f(r*): the change-rate threshold for a desired accuracy (§4).

        The fitted curve should be decreasing in r; a noisy quadratic can
        turn up before r = 1 (vertex v < 1), which would make a HIGHER
        desired accuracy produce a LARGER threshold (stop earlier).  Guard:
        use the monotone (running-min-from-the-left) envelope
        h*(r*) = min_{r' ≤ r*} f(r') — equal to f(r*) on the physical
        decreasing branch, clamped at f(v) beyond the vertex — with a small
        positive floor (h* ≤ 0 would never trigger)."""
        grid = jnp.linspace(0.0, desired_accuracy, 256)
        h = float(jnp.min(self.predict(grid)))
        return max(h, floor)


def _metrics(h: jnp.ndarray, pred: jnp.ndarray, n_params: int) -> FitMetrics:
    resid = h - pred
    sse = float(jnp.sum(resid ** 2))
    n = h.shape[0]
    rmse = float(jnp.sqrt(sse / max(n, 1)))
    ss_tot = float(jnp.sum((h - jnp.mean(h)) ** 2))
    r2 = 1.0 - sse / ss_tot if ss_tot > 0 else 1.0
    denom = n - n_params - 1
    adj = 1.0 - (1.0 - r2) * (n - 1) / denom if denom > 0 else r2
    return FitMetrics(sse=sse, rmse=rmse, r2=r2, adj_r2=adj)


def _polyfit(r: jnp.ndarray, h: jnp.ndarray, degree: int) -> jnp.ndarray:
    """Least-squares polynomial fit via QR on the Vandermonde matrix."""
    powers = jnp.stack([r ** p for p in range(degree + 1)], axis=-1)
    coeffs, *_ = jnp.linalg.lstsq(powers, h, rcond=None)
    return coeffs


def _lasso_quadratic(r: jnp.ndarray, h: jnp.ndarray, lam: float = 1e-4,
                     iters: int = 5000) -> jnp.ndarray:
    """Coordinate-descent LASSO on the quadratic basis (deterministic)."""
    X = jnp.stack([jnp.ones_like(r), r, r ** 2], axis=-1)
    col_sq = jnp.sum(X ** 2, axis=0)

    def body(_, beta):
        def update(j, b):
            resid = h - X @ b + X[:, j] * b[j]
            rho = jnp.dot(X[:, j], resid)
            bj = jnp.sign(rho) * jnp.maximum(jnp.abs(rho) - lam, 0.0) / jnp.maximum(col_sq[j], 1e-12)
            return b.at[j].set(bj)
        return jax.lax.fori_loop(0, 3, update, beta)

    return jax.lax.fori_loop(0, iters, body, jnp.zeros((3,), h.dtype))


def fit_family(r, h, family: str) -> RegressionModel:
    r = jnp.asarray(r, jnp.float32).reshape(-1)
    h = jnp.asarray(h, jnp.float32).reshape(-1)
    if family == "linear":
        c = _polyfit(r, h, 1)
    elif family == "quadratic":
        c = _polyfit(r, h, 2)
    elif family == "cubic":
        c = _polyfit(r, h, 3)
    elif family == "exponential":
        # h = a·exp(b·r) → log h = log a + b·r on h > eps points.
        eps = 1e-30
        mask = h > eps
        # keep shapes static: weight invalid points to 0 in the normal equations
        w = mask.astype(h.dtype)
        logh = jnp.log(jnp.maximum(h, eps))
        sw = jnp.sum(w)
        mr = jnp.sum(w * r) / jnp.maximum(sw, 1.0)
        ml = jnp.sum(w * logh) / jnp.maximum(sw, 1.0)
        cov = jnp.sum(w * (r - mr) * (logh - ml))
        var = jnp.sum(w * (r - mr) ** 2)
        b = cov / jnp.maximum(var, 1e-12)
        a = jnp.exp(ml - b * mr)
        c = jnp.stack([a, b])
    elif family == "lasso_quadratic":
        c = _lasso_quadratic(r, h)
    elif family == "log_quadratic":
        eps = 1e-30
        w = (h > eps).astype(h.dtype)
        logh = jnp.log(jnp.maximum(h, eps))
        X = jnp.stack([jnp.ones_like(r), r, r * r], axis=-1) * w[:, None]
        c, *_ = jnp.linalg.lstsq(X, logh * w, rcond=None)
    else:
        raise ValueError(f"unknown family {family}")
    coeffs = tuple(float(x) for x in np.asarray(c))
    model = RegressionModel(family=family, coeffs=coeffs,
                            metrics=FitMetrics(0, 0, 0, 0))
    pred = model.predict(r)
    return dataclasses.replace(model, metrics=_metrics(h, pred, len(coeffs)))


def select_model(r, h, families: Sequence[str] = FAMILIES) -> tuple[RegressionModel, dict]:
    """Fit every family; select by adjusted R² (paper §4: SSE/R²/adjR²/RMSE).

    Returns (best_model, {family: FitMetrics}) so benchmarks can report the
    whole comparison table (paper's internal-validity discussion, §5.5).
    """
    fits = {fam: fit_family(r, h, fam) for fam in families}
    table = {fam: m.metrics for fam, m in fits.items()}
    best = max(fits.values(), key=lambda m: m.metrics.adj_r2)
    return best, table


def rh_from_objectives(objectives: np.ndarray) -> np.ndarray:
    """h_i = |J_i − J_{i−1}| / |J_{i−1}| over a recorded objective sequence
    (Eq. 7 applied host-side) — one copy of the conversion every harvest /
    benchmark consumer used to hand-roll.  Returns h aligned with J[1:]."""
    js = np.asarray(objectives, np.float64).reshape(-1)
    return np.abs(np.diff(js)) / np.maximum(np.abs(js[:-1]), 1e-30)


def pool_traces(traces: Sequence[tuple[np.ndarray, np.ndarray]]):
    """Concatenate (r_i, h_i) traces from many training groups into one cloud.

    Drops the i=1 point of each trace (h₁ undefined, Eq. 7 starts at i=2) —
    callers pass aligned arrays where h[j] corresponds to r[j].
    """
    rs = np.concatenate([np.asarray(t[0], np.float64).reshape(-1) for t in traces])
    hs = np.concatenate([np.asarray(t[1], np.float64).reshape(-1) for t in traces])
    ok = np.isfinite(rs) & np.isfinite(hs)
    return rs[ok], hs[ok]


def balance_cloud(r: np.ndarray, h: np.ndarray, bins: int = 40):
    """r-binned geometric-mean aggregation of an (r, h) cloud.

    Long-tailed traces put most points at r ≈ 1; unweighted least squares
    then ignores the transition region the threshold lives in.  Balancing
    (one aggregate point per occupied r-bin; geometric mean because h spans
    decades) makes the fit see the whole accuracy range.  Beyond-paper
    robustification — the faithful path fits the raw cloud.
    """
    r = np.asarray(r, np.float64)
    h = np.asarray(h, np.float64)
    keep = h > 0
    r, h = r[keep], h[keep]
    if r.size == 0:
        return r, h
    edges = np.linspace(min(r.min(), 0.0), 1.0 + 1e-9, bins + 1)
    which = np.clip(np.digitize(r, edges) - 1, 0, bins - 1)
    rb, hb = [], []
    for b in range(bins):
        m = which == b
        if m.any():
            rb.append(r[m].mean())
            hb.append(np.exp(np.log(h[m]).mean()))
    return np.asarray(rb), np.asarray(hb)
