"""Cloud cost model (paper §3.3, Eq. 6/9/10) + the land-use case study (§5.4).

On-demand model: Cost = Price_unit × Time_comp,
Time_comp = Time_train + Time_actual, cost-effectiveness = T_actual / T_full.
Unit prices follow the paper's Amazon EC2 references; TPU v5e pricing is
added for the framework's own deployment target (beyond-paper, flagged).
"""
from __future__ import annotations

import dataclasses

# $/hour, on-demand (paper's references: m5.large for the case study,
# m4.2xlarge for the 50-instance illustration in §1).
EC2_ON_DEMAND_USD_PER_HOUR = {
    "m5.large": 0.096,
    "m4.2xlarge": 0.40,
    "m4.10xlarge": 2.00,
    "c5.18xlarge": 3.06,
}
# Beyond-paper: per-chip on-demand for the TPU deployment target.
TPU_ON_DEMAND_USD_PER_HOUR = {
    "v5e": 1.20,
    "v5p": 4.20,
}


@dataclasses.dataclass(frozen=True)
class CostReport:
    time_train_s: float
    time_actual_s: float
    time_full_s: float
    unit_price_per_hour: float
    n_instances: int = 1

    @property
    def time_comp_s(self) -> float:           # Eq. 9
        return self.time_train_s + self.time_actual_s

    @property
    def cost_effectiveness(self) -> float:    # Eq. 10 (lower = better)
        return self.time_actual_s / self.time_full_s

    @property
    def cost_actual_usd(self) -> float:       # Eq. 6
        return self.unit_price_per_hour * self.n_instances * self.time_comp_s / 3600.0

    @property
    def cost_full_usd(self) -> float:
        return self.unit_price_per_hour * self.n_instances * self.time_full_s / 3600.0

    @property
    def savings_usd(self) -> float:
        return self.cost_full_usd - self.cost_actual_usd

    @property
    def cost_train_usd(self) -> float:
        return self.unit_price_per_hour * self.n_instances * self.time_train_s / 3600.0


def report(time_actual_s: float, time_full_s: float, *, time_train_s: float = 0.0,
           instance: str = "m5.large", n_instances: int = 1,
           price_table: dict | None = None) -> CostReport:
    table = price_table or EC2_ON_DEMAND_USD_PER_HOUR
    return CostReport(time_train_s=time_train_s, time_actual_s=time_actual_s,
                      time_full_s=time_full_s,
                      unit_price_per_hour=table[instance],
                      n_instances=n_instances)


# --------------------------------------------------------------------------
# Land-use case study (paper §2.1, §5.4)
# --------------------------------------------------------------------------

CALIFORNIA_AREA_KM2 = 423_970.0
# One partitioned image (438×406 px at 1 ft/px) covers 16,520.74 m².
IMAGE_AREA_M2 = 16_520.74
US_AREA_KM2 = 9_833_520.0


def n_images_for_area(area_km2: float) -> float:
    return area_km2 * 1e6 / IMAGE_AREA_M2


def landuse_case_study(time_full_per_image_s: float, cost_effectiveness: float,
                       *, area_km2: float = CALIFORNIA_AREA_KM2,
                       time_train_s: float = 1169.46,
                       instance: str = "m5.large") -> CostReport:
    """Scale a per-image full-convergence time to a land-use statistics run.

    Paper numbers for reference: California ≈ 2.567e7 images, training took
    1169.46 s (once), 99%-accuracy clustering saved ≈19,256.73 h ≈ $4,082.43
    on m5.large; the US-wide run saves up to $94,687.49 per use.
    """
    n_img = n_images_for_area(area_km2)
    time_full = n_img * time_full_per_image_s
    time_actual = time_full * cost_effectiveness
    return report(time_actual, time_full, time_train_s=time_train_s,
                  instance=instance)
