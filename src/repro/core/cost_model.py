"""Cloud cost model (paper §3.3, Eq. 6/9/10) + the land-use case study (§5.4)
+ the spot-market extension the provisioning planner prices candidates with.

Paper equations implemented here (each property/function below names the one
it computes):

  · Eq. 6 — ``Cost = Price_unit × N_instances × Time_comp``: the on-demand
    billing model (``CostReport.cost_actual_usd`` / ``cost_full_usd``).
  · Eq. 9 — ``Time_comp = Time_train + Time_actual``: the one-off training
    phase (fitting h(r)) is amortised into the first run's bill
    (``CostReport.time_comp_s``).
  · Eq. 10 — ``cost-effectiveness = Time_actual / Time_full``: the fraction
    of the full-convergence cost the early-stopped run pays
    (``CostReport.cost_effectiveness``; the paper's headline 47.71–71.14%
    for k-means and 16.69–32.04% for EM at 99% accuracy).

Beyond-paper extension (flagged throughout): spot-market pricing.  The paper
prices on-demand m5.large instances only; the provisioning planner
(``repro.core.planner``) also considers preemptible capacity, which needs a
price *pair* per instance type plus an expected-restart model —
``Price`` / ``PriceTable`` / ``expected_spot_wall_s``.  Unit prices follow
the paper's Amazon EC2 references; TPU v5e/v5p pricing is added for the
framework's own deployment target.
"""
from __future__ import annotations

import dataclasses
import json

# $/hour, on-demand (paper's references: m5.large for the case study,
# m4.2xlarge for the 50-instance illustration in §1).
EC2_ON_DEMAND_USD_PER_HOUR = {
    "m5.large": 0.096,
    "m4.2xlarge": 0.40,
    "m4.10xlarge": 2.00,
    "c5.18xlarge": 3.06,
}
# Beyond-paper: per-chip on-demand for the TPU deployment target.
TPU_ON_DEMAND_USD_PER_HOUR = {
    "v5e": 1.20,
    "v5p": 4.20,
}


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Eq. 6/9/10 for one (early-stopped run, full-convergence baseline)
    pair: the paper's unit of cost accounting (§3.3, §5.4)."""
    time_train_s: float
    time_actual_s: float
    time_full_s: float
    unit_price_per_hour: float
    n_instances: int = 1

    @property
    def time_comp_s(self) -> float:
        """Eq. 9 — billed time: the amortised training phase plus the
        early-stopped production run."""
        return self.time_train_s + self.time_actual_s

    @property
    def cost_effectiveness(self) -> float:
        """Eq. 10 — Time_actual / Time_full (lower = better; 1.0 means the
        early stop saved nothing)."""
        return self.time_actual_s / self.time_full_s

    @property
    def cost_actual_usd(self) -> float:
        """Eq. 6 — Price_unit × N_instances × Time_comp for the
        early-stopped run (training amortised in, per Eq. 9)."""
        return self.unit_price_per_hour * self.n_instances * self.time_comp_s / 3600.0

    @property
    def cost_full_usd(self) -> float:
        """Eq. 6 applied to the full-convergence baseline (no training
        term: the reference run needs no fitted threshold)."""
        return self.unit_price_per_hour * self.n_instances * self.time_full_s / 3600.0

    @property
    def savings_usd(self) -> float:
        """cost_full − cost_actual: the dollars the long-tail cut saved
        (§5.4 reports this for the land-use case study)."""
        return self.cost_full_usd - self.cost_actual_usd

    @property
    def cost_train_usd(self) -> float:
        """Eq. 6 applied to the training phase alone — the one-off
        investment Eq. 9 amortises over repeated production use."""
        return self.unit_price_per_hour * self.n_instances * self.time_train_s / 3600.0


def report(time_actual_s: float, time_full_s: float, *, time_train_s: float = 0.0,
           instance: str = "m5.large", n_instances: int = 1,
           price_table: dict | None = None) -> CostReport:
    """Build the Eq. 6/9/10 report for a measured (actual, full) time pair.

    ``price_table`` maps instance name → on-demand $/h and defaults to the
    paper's EC2 references (``EC2_ON_DEMAND_USD_PER_HOUR``).
    """
    table = price_table or EC2_ON_DEMAND_USD_PER_HOUR
    return CostReport(time_train_s=time_train_s, time_actual_s=time_actual_s,
                      time_full_s=time_full_s,
                      unit_price_per_hour=table[instance],
                      n_instances=n_instances)


# --------------------------------------------------------------------------
# Spot-market price pairs + expected-restart model (beyond-paper: what the
# provisioning planner needs — see repro.core.planner and DV-ARPA in
# PAPERS.md for the pricing-aware provisioning direction)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Price:
    """One instance/accelerator quote: the paper's Eq. 6 unit price plus an
    optional spot quote with its interruption rate.

    ``preemption_per_hour`` is the expected number of interruptions per
    instance-hour (cloud providers publish interruption *frequencies*;
    0.05/h ≈ the "<5% per hour" band).  ``spot_per_hour=None`` means no
    preemptible capacity exists for this type (TPU pods, reserved metal).
    """
    name: str
    on_demand_per_hour: float
    spot_per_hour: float | None = None
    preemption_per_hour: float = 0.0

    def __post_init__(self):
        if self.on_demand_per_hour <= 0:
            raise ValueError(
                f"price {self.name!r}: on_demand_per_hour must be > 0, got "
                f"{self.on_demand_per_hour}")
        if self.spot_per_hour is not None and self.spot_per_hour <= 0:
            raise ValueError(
                f"price {self.name!r}: spot_per_hour must be > 0 (or None "
                f"for no spot capacity), got {self.spot_per_hour}")
        if self.preemption_per_hour < 0:
            raise ValueError(
                f"price {self.name!r}: preemption_per_hour must be >= 0, "
                f"got {self.preemption_per_hour}")

    @property
    def pricings(self) -> tuple[str, ...]:
        return (("on_demand", "spot") if self.spot_per_hour is not None
                else ("on_demand",))

    def rate(self, pricing: str) -> float:
        if pricing == "on_demand":
            return self.on_demand_per_hour
        if pricing == "spot":
            if self.spot_per_hour is None:
                raise ValueError(f"{self.name!r} has no spot quote")
            return self.spot_per_hour
        raise ValueError(f"unknown pricing {pricing!r} "
                         "(expected 'on_demand' or 'spot')")


@dataclasses.dataclass(frozen=True)
class PriceTable:
    """The planner's market view: a tuple of :class:`Price` quotes.

    JSON format (``from_json`` / ``plan --prices table.json``)::

        [{"name": "m5.large", "on_demand_per_hour": 0.096,
          "spot_per_hour": 0.035, "preemption_per_hour": 0.05}, ...]

    An empty table is constructible (so partial configs can be built up)
    but the planner rejects it loudly — there is nothing to choose from.
    """
    prices: tuple[Price, ...] = ()

    def __post_init__(self):
        names = [p.name for p in self.prices]
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            raise ValueError(f"duplicate price entries: {sorted(dupes)}")

    def __len__(self):
        return len(self.prices)

    def get(self, name: str) -> Price:
        for p in self.prices:
            if p.name == name:
                return p
        raise KeyError(f"no price entry {name!r}; table has "
                       f"{[p.name for p in self.prices]}")

    def names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.prices)

    @classmethod
    def default(cls) -> "PriceTable":
        """The paper's EC2 on-demand references + the TPU deployment
        targets, with representative spot quotes (~30% of on-demand at a
        5%/h interruption band — the planner's spot-vs-on-demand crossover
        tests sweep the rate, so these are starting points, not claims)."""
        rows = [Price(n, od, round(od * 0.30, 4), 0.05)
                for n, od in EC2_ON_DEMAND_USD_PER_HOUR.items()]
        rows += [Price(n, od, round(od * 0.40, 4), 0.08)
                 for n, od in TPU_ON_DEMAND_USD_PER_HOUR.items()]
        return cls(tuple(rows))

    @classmethod
    def from_json(cls, text: str) -> "PriceTable":
        rows = json.loads(text)
        if not isinstance(rows, list):
            raise ValueError("price table JSON must be a list of objects "
                             "(see PriceTable.from_json docstring)")
        return cls(tuple(Price(**r) for r in rows))

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(p) for p in self.prices],
                          indent=1)


def expected_spot_wall_s(wall_s: float, preemption_per_hour: float,
                         n_instances: int, *,
                         restart_overhead_s: float = 60.0,
                         checkpoint_interval_s: float | None = None) -> float:
    """Expected wall clock of a synchronous fleet on preemptible capacity.

    A synchronous fit stalls when ANY instance is interrupted, so the fleet
    interruption rate is ``λ = preemption_per_hour × n_instances`` (events/
    hour, independent-Poisson approximation).  Each interruption costs the
    restart overhead (re-provision + reload) plus the work since the last
    checkpoint — ``checkpoint_interval_s / 2`` in expectation, or half the
    run itself when nothing checkpoints (``None``, the conservative
    default: the engine's fit is one device loop today; per-iteration
    checkpointing is the ROADMAP's elastic-fleet item).  First-order in
    λT (interruptions are rare within one clustering run):

        E[T] ≈ T + λ·T_h × (restart_overhead + lost_work/2)

    Monotonically increasing in ``preemption_per_hour``, ``n_instances``
    and ``wall_s`` — the planner's spot-vs-on-demand crossover relies on
    this (tested in ``tests/test_planner.py``).
    """
    if wall_s < 0:
        raise ValueError(f"wall_s must be >= 0, got {wall_s}")
    lam = preemption_per_hour * max(n_instances, 1)   # fleet events/hour
    expected_events = lam * wall_s / 3600.0
    lost = (wall_s if checkpoint_interval_s is None
            else min(checkpoint_interval_s, wall_s))
    return wall_s + expected_events * (restart_overhead_s + lost / 2.0)


def priced_wall_s(wall_s: float, price: Price, n_instances: int,
                  pricing: str, *, restart_overhead_s: float = 60.0,
                  checkpoint_interval_s: float | None = None) -> float:
    """The wall clock a candidate is billed (and deadlined) at: the raw
    predicted wall on on-demand, the expected-restart-inflated wall on
    spot."""
    if pricing == "spot":
        return expected_spot_wall_s(
            wall_s, price.preemption_per_hour, n_instances,
            restart_overhead_s=restart_overhead_s,
            checkpoint_interval_s=checkpoint_interval_s)
    return wall_s


def candidate_cost_usd(wall_s: float, price: Price, n_instances: int,
                       pricing: str, *, restart_overhead_s: float = 60.0,
                       checkpoint_interval_s: float | None = None) -> float:
    """Eq. 6 priced at the chosen market: unit rate × instances × billed
    wall (expected-restart-inflated for spot — interrupted hours are still
    billed up to the interruption)."""
    billed = priced_wall_s(wall_s, price, n_instances, pricing,
                           restart_overhead_s=restart_overhead_s,
                           checkpoint_interval_s=checkpoint_interval_s)
    return price.rate(pricing) * n_instances * billed / 3600.0


# --------------------------------------------------------------------------
# Land-use case study (paper §2.1, §5.4)
# --------------------------------------------------------------------------

CALIFORNIA_AREA_KM2 = 423_970.0
# One partitioned image (438×406 px at 1 ft/px) covers 16,520.74 m².
IMAGE_AREA_M2 = 16_520.74
US_AREA_KM2 = 9_833_520.0


def n_images_for_area(area_km2: float) -> float:
    """§5.4 scaling: images needed to tile ``area_km2`` at the case
    study's partition size (438×406 px at 1 ft/px = 16,520.74 m²)."""
    return area_km2 * 1e6 / IMAGE_AREA_M2


def landuse_case_study(time_full_per_image_s: float, cost_effectiveness: float,
                       *, area_km2: float = CALIFORNIA_AREA_KM2,
                       time_train_s: float = 1169.46,
                       instance: str = "m5.large") -> CostReport:
    """Scale a per-image full-convergence time to a land-use statistics run
    (§5.4), applying Eq. 9/10 at survey scale.

    Paper numbers for reference: California ≈ 2.567e7 images, training took
    1169.46 s (once), 99%-accuracy clustering saved ≈19,256.73 h ≈ $4,082.43
    on m5.large; the US-wide run saves up to $94,687.49 per use.
    ``docs/cost_planning.md`` walks this calculation and hands it to the
    provisioning planner.
    """
    n_img = n_images_for_area(area_km2)
    time_full = n_img * time_full_per_image_s
    time_actual = time_full * cost_effectiveness
    return report(time_actual, time_full, time_train_s=time_train_s,
                  instance=instance)
