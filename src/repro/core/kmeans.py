"""k-means (Lloyd) in JAX — MXU-shaped, distributable, early-stoppable.

Assignment uses the identity ‖x−c‖² = ‖x‖² − 2·x·cᵀ + ‖c‖² so the dominant
cost is an [N,D]×[D,K] matmul (TPU adaptation, DESIGN.md §2).  One fused pass
produces labels, per-cluster sums/counts and the objective J — the same
contract the Pallas kernel (``repro.kernels.kmeans_assign``) implements.

Three drivers — all thin wrappers over ``repro.core.engine`` since ISSUE 1:
  · ``kmeans_fit_traced``     — host loop, records (J_i, labels_i) per
    iteration; used on *training groups* to harvest (r_i, h_i) pairs.
  · ``kmeans_fit_earlystop``  — ``lax.while_loop`` with the h ≤ h* predicate
    **on device**; the production path (§4).
  · ``kmeans_fit_full``       — run to convergence: stops only when the
    centroids freeze (the paper's 100%-accuracy reference, Time_full).

All three accept ``axis_name`` so the same code runs under ``shard_map`` with
points sharded over the data axes: the only cross-shard traffic per iteration
is a psum of [K,D]+[K]+[1] statistics.  ``chunks`` streams the assignment
pass over N/C-sized pieces (see the engine docstring).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def assign_and_stats(x, centroids, axis_name=None, use_kernel: bool = False,
                     mask=None, kernel_backend: str | None = None):
    """Fused assignment pass.

    Returns (labels [N] int32, sums [K,D] f32, counts [K] f32, j []).
    ``axis_name``: psum the statistics over those mesh axes (shard_map mode).
    ``use_kernel``: route through the kernel dispatch layer
    (``repro.kernels.dispatch``: tpu/gpu Pallas, interpret elsewhere;
    ``kernel_backend`` forces a registry backend).
    ``mask``: [N] f32 row weights (streaming-chunk padding) — honoured by
    both the jnp and the kernel path (the kernels take a weight operand).
    """
    if use_kernel:
        from repro.kernels.kmeans_assign import ops as _kops
        labels, sums, counts, j = _kops.kmeans_assign(
            x, centroids, mask=mask, backend=kernel_backend)
    else:
        x = x.astype(jnp.float32)
        c = centroids.astype(jnp.float32)
        x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # [N,1]
        c2 = jnp.sum(c * c, axis=-1)                         # [K]
        d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]              # [N,K] (MXU matmul)
        labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
        mind2 = jnp.maximum(jnp.min(d2, axis=-1), 0.0)       # clamp fp cancellation
        k = centroids.shape[0]
        if mask is None:
            j = jnp.sum(mind2)
            sums = jnp.zeros_like(c).at[labels].add(x)
            counts = jnp.zeros((k,), jnp.float32).at[labels].add(1.0)
        else:
            mask = mask.astype(jnp.float32)
            j = jnp.sum(mind2 * mask)
            sums = jnp.zeros_like(c).at[labels].add(x * mask[:, None])
            counts = jnp.zeros((k,), jnp.float32).at[labels].add(mask)
            # weight-0 rows are labelled -1 — the kernel ops' mask contract
            labels = jnp.where(mask > 0, labels, -1)
    if axis_name is not None:
        sums = jax.lax.psum(sums, axis_name)
        counts = jax.lax.psum(counts, axis_name)
        j = jax.lax.psum(j, axis_name)
    return labels, sums, counts, j


def update_centroids(centroids, sums, counts):
    """New centroid = mean of members; empty clusters keep their old centroid."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    new = sums / safe
    return jnp.where(counts[:, None] > 0, new, centroids)


def minibatch_update_centroids(centroids, sums, counts, v, decay: float = 1.0):
    """Per-cluster learning-rate update (Sculley 2010, web-scale k-means).

    ``v`` accumulates how many points each cluster has ever absorbed; the
    batched form of the per-point rule c ← (1−1/v)c + (1/v)x is

        v_k ← decay·v_k + n_k        (n_k = batch count for cluster k)
        c_k ← c_k + (n_k / v_k) · (mean_batch_k − c_k)

    so the step size 1/v_k anneals like 1/t and the centroids converge even
    though every iteration only sees a subsample.  ``decay`` < 1 adds
    exponential forgetting (the step size no longer vanishes — useful for
    drifting streams); ``decay`` = 1 is Sculley's schedule exactly.  The
    first batch a cluster sees has n_k = v_k, i.e. a full Lloyd step.

    Sharded contract (shard_map): ``sums``/``counts`` must arrive already
    psum'd over the data axes — the engine reduces the shard-local batch
    stats *before* calling this rule — so ``v`` accumulates GLOBAL
    per-cluster counts, the 1/t step size anneals on the global point
    stream, and (params, v) stay bitwise replicated across shards without
    any further collective.  Feeding shard-local counts instead would both
    shrink the steps (B/shards points per batch) and de-synchronise v
    wherever shard contents differ.

    Returns (new_centroids, new_v); clusters with no batch members keep both.
    """
    v_new = decay * v + counts
    eta = counts / jnp.maximum(v_new, 1.0)
    target = sums / jnp.maximum(counts, 1.0)[:, None]
    new = centroids + eta[:, None] * (target - centroids)
    return jnp.where(counts[:, None] > 0, new, centroids), v_new


def kmeans_step(x, centroids, axis_name=None, use_kernel: bool = False):
    """One Lloyd iteration. Returns (new_centroids, labels, j)."""
    labels, sums, counts, j = assign_and_stats(x, centroids, axis_name, use_kernel)
    return update_centroids(centroids, sums, counts), labels, j


# --------------------------------------------------------------------------
# Chunk layout (shared by the engine's streaming sweep and the ++ init) —
# one copy in kernels.layout since ISSUE 4, re-exported from its
# historical home here.
# --------------------------------------------------------------------------

from repro.kernels.layout import chunk_points  # noqa: E402,F401


# --------------------------------------------------------------------------
# Initialisation
# --------------------------------------------------------------------------

def random_init(key, x, k: int):
    """k distinct data points chosen uniformly."""
    idx = jax.random.choice(key, x.shape[0], shape=(k,), replace=False)
    return x[idx].astype(jnp.float32)


def _min_d2_scan(xc, mask, c, d2):
    """d2 ← min(d2, ‖x − c‖²) streamed chunk-by-chunk ([C, P] in, [C, P] out).

    Padded rows stay pinned at 0 so they carry no sampling mass; the [P, D]
    difference tensor exists for one chunk at a time only.
    """
    def body(_, inp):
        xi, mi, d2i = inp
        diff = xi - c[None, :]
        nd = jnp.minimum(d2i, jnp.sum(diff * diff, axis=-1))
        return None, jnp.where(mi > 0, nd, 0.0)

    _, out = jax.lax.scan(body, None, (xc, mask, d2))
    return out


def kmeans_plus_plus_init(key, x, k: int, chunks: int = 1):
    """k-means++ seeding (D² sampling), streamed over ``chunks`` pieces.

    The running min-distance table lives as [C, P] alongside the [C, P, D]
    chunk layout from :func:`chunk_points`; each of the k−1 D² draws is the
    exact hierarchical factorisation of the flat categorical —  pick a chunk
    with probability ∝ its d² mass, then a row within it ∝ d² — so the
    distribution is identical for every chunking, and the per-step temporary
    is one chunk's [P, D] difference, never a resident [N, D] (or any [N, K])
    intermediate.  The key schedule matches the historical monolithic
    implementation (one split per draw; the chunk pick uses a ``fold_in`` of
    the same sub-key and is deterministic when C = 1), so ``chunks=1``
    reproduces the flat pass bit-for-bit (property-tested) and existing
    seeds are unchanged.
    """
    x = x.astype(jnp.float32)
    n = x.shape[0]
    xc, mask = chunk_points(x, chunks)
    n_chunks, per = mask.shape

    key, sub = jax.random.split(key)
    flat = jax.random.randint(sub, (), 0, n)
    first = xc[flat // per, flat % per]
    centroids = jnp.zeros((k, x.shape[1]), jnp.float32).at[0].set(first)
    d2 = _min_d2_scan(xc, mask, first,
                      jnp.where(mask > 0, jnp.inf, 0.0))

    def body(i, carry):
        centroids, d2, key = carry
        key, sub = jax.random.split(key)
        w = jnp.sum(d2, axis=1)                                  # [C] mass
        ci = jax.random.choice(jax.random.fold_in(sub, 1), n_chunks,
                               p=w / jnp.maximum(jnp.sum(w), 1e-30))
        row = d2[ci]
        ri = jax.random.choice(sub, per,
                               p=row / jnp.maximum(jnp.sum(row), 1e-30))
        c = xc[ci, ri]
        centroids = centroids.at[i].set(c)
        return centroids, _min_d2_scan(xc, mask, c, d2), key

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids, d2, key))
    return centroids


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------

def kmeans_fit_traced(x, centroids0, max_iters: int = 300,
                      use_kernel: bool = False, chunks: int = 1):
    """Host-side loop recording the per-iteration history (training groups).

    Returns dict with: labels_history [T,N], objectives [T], final labels,
    centroids, and n_iters.  Runs until the partition is stable or max_iters.
    """
    from .engine import ClusteringEngine, EngineConfig
    eng = ClusteringEngine("kmeans", EngineConfig(use_kernel=use_kernel,
                                                  chunks=chunks))
    centroids = jnp.asarray(centroids0, jnp.float32)
    x = jnp.asarray(x)
    labels_hist, js = [], []
    prev_labels = None
    for _ in range(max_iters):
        centroids, labels, j = eng.step(x, centroids)
        labels_hist.append(labels)
        js.append(float(j))
        if prev_labels is not None and bool(jnp.all(labels == prev_labels)):
            break
        prev_labels = labels
    return {
        "labels_history": jnp.stack(labels_hist),
        "objectives": jnp.asarray(js),
        "labels": labels_hist[-1],
        "centroids": centroids,
        "n_iters": len(js),
    }


def trace_accuracy(labels_history, k: int):
    """r_i = Rand(P_i, P_f) for every recorded iteration (paper §3.2)."""
    from .rand_index import rand_index
    final = labels_history[-1]
    # host call → the exact integer path in rand_index (no jit: tracing
    # would demote the pair counts to float32)
    return jnp.asarray([float(rand_index(labels_history[i], final, ka=k, kb=k))
                        for i in range(labels_history.shape[0])])


def trace_to_rh(result, k: int):
    """(r_i, h_i) pairs for regression fitting. h starts at i=2 (Eq. 7)."""
    js = result["objectives"]
    r = trace_accuracy(result["labels_history"], k)
    h = jnp.abs(js[1:] - js[:-1]) / jnp.maximum(jnp.abs(js[:-1]), 1e-30)
    return r[1:], h


def kmeans_fit_earlystop(x, centroids0, h_star, max_iters: int = 300,
                         axis_name=None, use_kernel: bool = False,
                         patience: int = 1, chunks: int = 1):
    """Production driver: lax.while_loop, stop when h_i ≤ h* (on device).

    ``patience`` requires that many CONSECUTIVE sub-threshold readings —
    h is not monotone iteration-to-iteration (plateau → re-acceleration),
    and a single early dip must not trigger the stop (robustification; the
    paper's first-crossing rule is patience=1).

    The stop decision is computed from globally psum'd statistics, so every
    shard sees the same h_i and the loop cannot diverge across devices.
    Returns (centroids, labels, j, n_iters).
    """
    from .engine import ClusteringEngine, EngineConfig
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=max_iters, patience=patience, chunks=chunks,
        axis_name=axis_name, use_kernel=use_kernel,
        use_h_stop=True, stop_when_frozen=True))
    res = eng.fit(x, centroids0, h_star=h_star)
    return res.params, res.labels, res.objective, res.n_iters


def kmeans_fit_full(x, centroids0, max_iters: int = 1000, axis_name=None,
                    use_kernel: bool = False, chunks: int = 1):
    """Run to full convergence: stop only when the centroids freeze.

    Deliberately NOT ``h* = 0``: near convergence the fp32 objective can
    plateau bit-for-bit (ΔJ below J's ulp) while boundary points are still
    migrating, so an h-based stop with h*=0 / patience=1 would return
    centroids that are not a Lloyd fixed point (ISSUE 1 regression).
    """
    from .engine import ClusteringEngine, EngineConfig
    eng = ClusteringEngine("kmeans", EngineConfig(
        max_iters=max_iters, chunks=chunks, axis_name=axis_name,
        use_kernel=use_kernel, use_h_stop=False, stop_when_frozen=True))
    res = eng.fit(x, centroids0)
    return res.params, res.labels, res.objective, res.n_iters
