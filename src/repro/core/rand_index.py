"""Rand index between two partitions — the paper's accuracy metric (Eq. 5).

The paper defines the Rand index over all n·(n−1)/2 point pairs.  At the
paper's own SpaceNet scale (>3.1e9 points) the pair formulation is not even
representable, so we use the exact contingency-table identity:

    n11        = Σ_ij C(N_ij, 2)                (pairs together in both)
    n11 + n10  = Σ_i  C(A_i, 2)   A_i = Σ_j N_ij (pairs together in P1)
    n11 + n01  = Σ_j  C(B_j, 2)   B_j = Σ_i N_ij (pairs together in P2)
    n00        = C(n,2) − n11 − n10 − n01
    Rand       = (n11 + n00) / C(n, 2)

This is algebraically identical to Eq. 5, computed in O(n + k²) instead of
O(n²).  The contingency matrix is a scatter-add, which under a data-sharded
mesh becomes a local scatter + one small [k,k] all-reduce — the distributed
form used by the clustering engine.

**Exactness.**  The public functions are hybrid: on concrete (host) inputs
— every certification call site: the CLI's achieved-accuracy validation,
the benchmarks, the CI gates — the contingency table is accumulated in
int64 (streamed through the device scatter-add in int32-safe row chunks)
and the C(n,2) arithmetic runs in arbitrary-precision Python integers, so
the result is exact at any N, including the paper's >3.1e9-point scale
where C(n,2) ≈ 4.8e18 overflows int32 *and* exceeds float64's 2^53
exact-integer range.  Under a jit trace (the in-graph harvest path, group
scale) the same identity runs in float32 — exact only while every
pair count stays below 2^24 (n ≈ 6000 rows per cell), documented and
acceptable for regression-fit targets but not for certification, which is
why nothing in the certification path calls the traced form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# rows per streamed scatter chunk: each chunk's per-cell count is bounded by
# the chunk length, so the device-side int32 accumulation stays exact
_EXACT_CHUNK_ROWS = 1 << 24


def _is_traced(*arrays) -> bool:
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def _comb2(x: jnp.ndarray) -> jnp.ndarray:
    """C(x, 2) = x(x−1)/2 elementwise — float32 under trace (see module
    docstring for the exactness bound), float64 when x64 is on."""
    x = x.astype(jnp.float64) if jax.config.read("jax_enable_x64") else x.astype(jnp.float32)
    return x * (x - 1.0) / 2.0


def _comb2_int(x: int) -> int:
    """Exact C(x, 2) in arbitrary-precision host integers."""
    return x * (x - 1) // 2


def contingency_table(labels_a: jnp.ndarray, labels_b: jnp.ndarray,
                      ka: int, kb: int) -> jnp.ndarray:
    """[ka, kb] counts of points with (label_a=i, label_b=j).  O(n)
    scatter-add on device; int32, so exact only below 2^31 rows per cell —
    the streaming int64 accumulation for host inputs lives in
    :func:`contingency_table_exact`."""
    flat = labels_a.astype(jnp.int32) * kb + labels_b.astype(jnp.int32)
    counts = jnp.zeros((ka * kb,), dtype=jnp.int32).at[flat.reshape(-1)].add(1)
    return counts.reshape(ka, kb)


def contingency_table_exact(labels_a, labels_b, ka: int, kb: int,
                            chunk_rows: int = _EXACT_CHUNK_ROWS) -> np.ndarray:
    """Exact int64 contingency table for concrete label vectors of any
    length: the rows stream through the device scatter-add in chunks short
    enough that every per-cell count fits int32 exactly, and the per-chunk
    tables accumulate on host in int64."""
    n = int(np.shape(labels_a)[-1] if np.ndim(labels_a) else 0)
    out = np.zeros((ka, kb), np.int64)
    for lo in range(0, n, chunk_rows):
        hi = min(lo + chunk_rows, n)
        out += np.asarray(
            contingency_table(jnp.asarray(labels_a[lo:hi]),
                              jnp.asarray(labels_b[lo:hi]), ka, kb),
            np.int64)
    return out


def _rand_from_table_exact(table: np.ndarray) -> float:
    """Exact Rand from a host contingency table via Python-int arithmetic
    (no float rounding until the final correctly-rounded division)."""
    cells = [int(v) for v in np.asarray(table, np.int64).ravel()]
    n = sum(cells)
    total = _comb2_int(n)
    if total == 0:
        # single point (or empty) partition: identical by vacuity
        return 1.0
    n11 = sum(_comb2_int(v) for v in cells)
    t = np.asarray(table, np.int64)
    same_a = sum(_comb2_int(int(v)) for v in t.sum(axis=1))
    same_b = sum(_comb2_int(int(v)) for v in t.sum(axis=0))
    n00 = total - same_a - same_b + n11
    return (n11 + n00) / total


def rand_index_from_contingency(table) -> jnp.ndarray:
    """Rand index from a contingency table — exact (Python-int arithmetic)
    for concrete tables, float32 identity under a jit trace."""
    if not _is_traced(table):
        return np.float64(_rand_from_table_exact(np.asarray(table)))
    table = table.astype(jnp.float32)
    n = jnp.sum(table)
    total_pairs = _comb2(n)
    n11 = jnp.sum(_comb2(table))
    same_a = jnp.sum(_comb2(jnp.sum(table, axis=1)))   # n11 + n10
    same_b = jnp.sum(_comb2(jnp.sum(table, axis=0)))   # n11 + n01
    n00 = total_pairs - same_a - same_b + n11
    # Single point (or empty) partition: define Rand = 1 (identical by vacuity).
    return jnp.where(total_pairs > 0, (n11 + n00) / jnp.maximum(total_pairs, 1.0), 1.0)


def rand_index(labels_a: jnp.ndarray, labels_b: jnp.ndarray,
               ka: int, kb: int):
    """Rand(P_a, P_b) for dense integer label vectors.

    Concrete inputs take the exact path (int64 streamed contingency +
    arbitrary-precision pair counts — exact at any N); traced inputs fall
    back to the float32 in-graph identity.
    """
    if not _is_traced(labels_a, labels_b):
        return np.float64(_rand_from_table_exact(
            contingency_table_exact(labels_a, labels_b, ka, kb)))
    return rand_index_from_contingency(contingency_table(labels_a, labels_b, ka, kb))


def rand_index_pairwise_reference(labels_a, labels_b) -> float:
    """O(n²) literal implementation of the paper's Eq. 5 — test oracle only."""
    a = np.asarray(labels_a).reshape(-1)
    b = np.asarray(labels_b).reshape(-1)
    n = a.shape[0]
    same_a = a[:, None] == a[None, :]
    same_b = b[:, None] == b[None, :]
    iu = np.triu_indices(n, k=1)
    agree = (same_a[iu] == same_b[iu]).sum()
    total = n * (n - 1) // 2
    return float(agree) / total if total else 1.0


def adjusted_rand_index(labels_a, labels_b, ka: int, kb: int):
    """ARI — chance-corrected variant, reported alongside Rand in benchmarks.

    Concrete inputs run in float64 from the exact int64 table; traced
    inputs fall back to float32.
    """
    if not _is_traced(labels_a, labels_b):
        t = contingency_table_exact(labels_a, labels_b, ka, kb).astype(np.float64)
        n = t.sum()
        sum_ij = _comb2_np(t).sum()
        sum_a = _comb2_np(t.sum(axis=1)).sum()
        sum_b = _comb2_np(t.sum(axis=0)).sum()
        total = max(n * (n - 1.0) / 2.0, 1.0)
        expected = sum_a * sum_b / total
        max_index = 0.5 * (sum_a + sum_b)
        denom = max_index - expected
        return np.float64(1.0 if abs(denom) <= 1e-12
                          else (sum_ij - expected) / denom)
    table = contingency_table(labels_a, labels_b, ka, kb).astype(jnp.float32)
    n = jnp.sum(table)
    sum_ij = jnp.sum(_comb2(table))
    sum_a = jnp.sum(_comb2(jnp.sum(table, axis=1)))
    sum_b = jnp.sum(_comb2(jnp.sum(table, axis=0)))
    total = _comb2(n)
    expected = sum_a * sum_b / jnp.maximum(total, 1.0)
    max_index = 0.5 * (sum_a + sum_b)
    denom = max_index - expected
    return jnp.where(jnp.abs(denom) > 1e-12, (sum_ij - expected) / denom, 1.0)


def _comb2_np(x: np.ndarray) -> np.ndarray:
    return x * (x - 1.0) / 2.0


def sharded_contingency(labels_a: jnp.ndarray, labels_b: jnp.ndarray,
                        ka: int, kb: int, axis_name: str | tuple[str, ...]):
    """Contingency under shard_map: local scatter-add + psum over the data axes."""
    local = contingency_table(labels_a, labels_b, ka, kb)
    return jax.lax.psum(local, axis_name)
