"""Rand index between two partitions — the paper's accuracy metric (Eq. 5).

The paper defines the Rand index over all n·(n−1)/2 point pairs.  At the
paper's own SpaceNet scale (>3.1e9 points) the pair formulation is not even
representable, so we use the exact contingency-table identity:

    n11        = Σ_ij C(N_ij, 2)                (pairs together in both)
    n11 + n10  = Σ_i  C(A_i, 2)   A_i = Σ_j N_ij (pairs together in P1)
    n11 + n01  = Σ_j  C(B_j, 2)   B_j = Σ_i N_ij (pairs together in P2)
    n00        = C(n,2) − n11 − n10 − n01
    Rand       = (n11 + n00) / C(n, 2)

This is algebraically identical to Eq. 5, computed in O(n + k²) instead of
O(n²).  The contingency matrix is a scatter-add, which under a data-sharded
mesh becomes a local scatter + one small [k,k] all-reduce — the distributed
form used by the clustering engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _comb2(x: jnp.ndarray) -> jnp.ndarray:
    """C(x, 2) = x(x−1)/2, elementwise, in float64-safe integer arithmetic."""
    x = x.astype(jnp.float64) if jax.config.read("jax_enable_x64") else x.astype(jnp.float32)
    return x * (x - 1.0) / 2.0


def contingency_table(labels_a: jnp.ndarray, labels_b: jnp.ndarray,
                      ka: int, kb: int) -> jnp.ndarray:
    """[ka, kb] counts of points with (label_a=i, label_b=j).  O(n) scatter-add."""
    flat = labels_a.astype(jnp.int32) * kb + labels_b.astype(jnp.int32)
    counts = jnp.zeros((ka * kb,), dtype=jnp.int32).at[flat.reshape(-1)].add(1)
    return counts.reshape(ka, kb)


def rand_index_from_contingency(table: jnp.ndarray) -> jnp.ndarray:
    """Exact Rand index from a contingency table (any integer dtype)."""
    table = table.astype(jnp.float32)
    n = jnp.sum(table)
    total_pairs = _comb2(n)
    n11 = jnp.sum(_comb2(table))
    same_a = jnp.sum(_comb2(jnp.sum(table, axis=1)))   # n11 + n10
    same_b = jnp.sum(_comb2(jnp.sum(table, axis=0)))   # n11 + n01
    n00 = total_pairs - same_a - same_b + n11
    # Single point (or empty) partition: define Rand = 1 (identical by vacuity).
    return jnp.where(total_pairs > 0, (n11 + n00) / jnp.maximum(total_pairs, 1.0), 1.0)


@functools.partial(jax.jit, static_argnames=("ka", "kb"))
def rand_index(labels_a: jnp.ndarray, labels_b: jnp.ndarray,
               ka: int, kb: int) -> jnp.ndarray:
    """Rand(P_a, P_b) for dense integer label vectors."""
    return rand_index_from_contingency(contingency_table(labels_a, labels_b, ka, kb))


def rand_index_pairwise_reference(labels_a, labels_b) -> float:
    """O(n²) literal implementation of the paper's Eq. 5 — test oracle only."""
    import numpy as np
    a = np.asarray(labels_a).reshape(-1)
    b = np.asarray(labels_b).reshape(-1)
    n = a.shape[0]
    same_a = a[:, None] == a[None, :]
    same_b = b[:, None] == b[None, :]
    iu = np.triu_indices(n, k=1)
    agree = (same_a[iu] == same_b[iu]).sum()
    total = n * (n - 1) // 2
    return float(agree) / total if total else 1.0


def adjusted_rand_index(labels_a, labels_b, ka: int, kb: int) -> jnp.ndarray:
    """ARI — chance-corrected variant, reported alongside Rand in benchmarks."""
    table = contingency_table(labels_a, labels_b, ka, kb).astype(jnp.float32)
    n = jnp.sum(table)
    sum_ij = jnp.sum(_comb2(table))
    sum_a = jnp.sum(_comb2(jnp.sum(table, axis=1)))
    sum_b = jnp.sum(_comb2(jnp.sum(table, axis=0)))
    total = _comb2(n)
    expected = sum_a * sum_b / jnp.maximum(total, 1.0)
    max_index = 0.5 * (sum_a + sum_b)
    denom = max_index - expected
    return jnp.where(jnp.abs(denom) > 1e-12, (sum_ij - expected) / denom, 1.0)


def sharded_contingency(labels_a: jnp.ndarray, labels_b: jnp.ndarray,
                        ka: int, kb: int, axis_name: str | tuple[str, ...]):
    """Contingency under shard_map: local scatter-add + psum over the data axes."""
    local = contingency_table(labels_a, labels_b, ka, kb)
    return jax.lax.psum(local, axis_name)
