"""Configuration-matched long-tail training (the paper's §4–§5.4 pipeline,
harvested under the production engine regime).

The paper's contribution is the *training phase*: run sample groups to
convergence, harvest (accuracy r_i, change-rate h_i) pairs, fit h = f(r)
(Eq. 8 family comparison) and reuse h* = f(r*) forever.  The original
repo fitted that regression only from full-batch traces replayed host-side
(``kmeans_fit_traced`` step loops) and *transferred* h* to minibatch /
kernel / sharded production runs via the paired Eq. 7 stop.  That works —
the pairing keeps the h scale compatible — but the ROADMAP (and the
cost-aware cloud tooling in PAPERS.md: D-SPACE4Cloud, DV-ARPA) is explicit
that a performance model should be trained under the configuration it will
serve.  This module is that trainer:

  · ``harvest_traces`` runs each training group through the engine's fit
    drivers with ``EngineConfig(trace=True)`` — full, minibatch, restarts,
    sharded, with or without ``use_kernel`` — so the recorded h sequence is
    the *exact* statistic the production stop will compare against h*
    (paired same-subsample rate in minibatch mode, psum'd stats under
    shard_map, kernel fp32 accumulation order under ``use_kernel``).
    Accuracy r_i is then read off the recorded parameter trajectory: one
    batched assignment pass per trace (``lax.map`` over the [T, ...]
    params history) labels every iteration's partition, and r_i is the
    Rand index against the *full-batch reference partition* — the
    paper's §3.2 definition (accuracy relative to the converged result).
    Full-mode harvests already end at that partition, so they
    self-reference; minibatch harvests run one cheap offline full-batch
    fit per training group (``reference_partition``) so the fit target
    aligns exactly with the validation metric.

  · ``fit_for_config`` pools those traces, runs the Eq. 8 family
    comparison (or a pinned family) and stamps the harvest regime into
    ``LongTailModel.engine_config`` — ``EngineConfig.from_longtail``
    compares that provenance against the production config and warns
    loudly on a mismatch.

``BENCH_longtail_matched.json`` (benchmarks/run.py ``longtail_matched``)
tracks the payoff: the matched fit's achieved-accuracy spread vs the
transferred full-batch h* on the same held-out groups.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import em_gmm as _em
from . import kmeans as _km
from .earlystop import LongTailModel, fit_longtail
from .engine import ClusteringEngine, EngineConfig, Trace, get_algorithm
from .rand_index import contingency_table, rand_index_from_contingency

# EM full-batch harvest stop: relative log-likelihood change below the
# legacy em_fit_traced tolerance counts as converged.
_EM_TOL = 1e-12


def config_fingerprint(config: EngineConfig, devices: int = 1) -> dict:
    """The regime a harvest ran under, as JSON-stampable provenance.

    ``devices`` records the mesh size for the record only —
    ``EngineConfig.from_longtail`` does not warn on it, because the sharded
    drivers reproduce the single-device trajectory up to fp32 reduction
    order (chunk-global layout + replicated draws, regression-tested), so
    a model fitted on 1 device serves an 8-device mesh mode-matched.
    """
    d = config.matched_fingerprint()
    d["devices"] = int(devices)
    return d


def harvest_config(production: EngineConfig, algorithm: str, *,
                   max_iters: int | None = None,
                   seed: int | None = None) -> EngineConfig:
    """Derive the trace-harvest config from the production config.

    Everything regime-defining (mode, chunk layout, batch_chunks, decay,
    ema, kernel routing) is kept; only the stop is re-aimed at *full
    convergence* so the trace covers the whole tail the regression must
    see: k-means full mode stops on frozen centroids (an h-based stop at
    h*=0 quits on fp32 J plateaus before the Lloyd fixed point), EM full
    mode stops at the legacy ``em_fit_traced`` tolerance, and minibatch
    mode runs until the paired rate sits at exactly 0 with patience (or
    ``max_iters`` — learning-rate updates have no frozen fixed point).
    """
    kw: dict = dict(trace=True, h_star=0.0)
    if max_iters is not None:
        kw["max_iters"] = max_iters
    if production.mode == "minibatch":
        kw.update(use_h_stop=True, stop_when_frozen=False,
                  patience=max(production.patience, 3))
        if seed is not None:
            kw["seed"] = seed
    elif algorithm == "kmeans":
        kw.update(use_h_stop=False, stop_when_frozen=True)
    else:
        kw.update(use_h_stop=True, h_star=_EM_TOL, patience=1,
                  stop_when_frozen=False)
    return dataclasses.replace(production, **kw)


@dataclasses.dataclass(frozen=True)
class TrainingPlan:
    """What to harvest and fit: algorithm, k, and — the point of this
    module — the production :class:`EngineConfig` the traces must be
    recorded under.  ``restarts`` > 1 harvests every restart's trace from
    one vmapped fleet per group (R traces per group for the price of one
    batched program); ``max_iters`` overrides the harvest iteration budget
    without touching the production config; ``family=None`` runs the
    Eq. 8 model-selection comparison and keeps the winner."""
    algorithm: str = "kmeans"
    k: int = 2
    config: EngineConfig = EngineConfig()
    family: str | None = "quadratic"
    balanced: bool = False
    restarts: int = 1
    max_iters: int | None = None
    seed: int = 0
    dataset: str = "train"


def _group_init(algorithm: str, key, x, k: int, chunks: int):
    """Per-group seeding, matching the production CLI's convention:
    streamed k-means++ for k-means, k-means++-seeded GMMs for EM."""
    c0 = _km.kmeans_plus_plus_init(key, x, k, chunks=chunks)
    if algorithm == "kmeans":
        return c0
    return _em.init_from_kmeans(x, c0)


@functools.partial(jax.jit, static_argnames=("algorithm",))
def _trace_labels(x, params_hist, algorithm: str):
    """[T, N] labels: one full assignment pass per recorded iteration,
    sequential over the trace axis (``lax.map``) so the per-step [N, K]
    intermediate never batches up."""
    alg = get_algorithm(algorithm)
    ones = jnp.ones((x.shape[0],), jnp.float32)

    def one(p):
        labels, _ = alg.chunk_stats(x, ones, p)
        return labels

    return jax.lax.map(one, params_hist)


@functools.partial(jax.jit, static_argnames=("k",))
def _trace_rand(labels_hist, ref_labels, k: int):
    """[T] Rand(P_t, P_ref) — the paper's accuracy metric per iteration."""
    def one(lab):
        return rand_index_from_contingency(
            contingency_table(lab, ref_labels, k, k))

    return jax.lax.map(one, labels_hist)


def engine_trace_to_rh(trace: Trace, x, *, algorithm: str, k: int,
                       ref_labels=None) -> tuple[np.ndarray, np.ndarray]:
    """(r_i, h_i) pairs from one engine trace (§3.2 accuracy + Eq. 7 rate).

    Distinct name from the legacy ``core.trace_to_rh`` (which consumes a
    ``kmeans_fit_traced`` result dict) — this one consumes the engine's
    :class:`Trace`.  ``ref_labels`` is the reference partition accuracy is
    measured against; ``None`` falls back to the trace's own final
    recorded state (the legacy semantics — exact for full-mode harvests,
    which run to the converged partition anyway).  ``harvest_traces``
    passes the *full-batch* reference partition for minibatch harvests,
    where the trace's own endpoint is a subsample approximation and
    self-reference would inflate every r_i (the ROADMAP carry-over this
    fixes): the fit target then aligns exactly with the validation metric.
    Rows with no iteration behind them (mask 0) or an undefined rate
    (h = inf at index 0 of a full-mode trace) are dropped.
    """
    mask = np.asarray(trace.mask)
    h = np.asarray(trace.h, np.float64)
    n_it = int(mask.sum())
    if n_it == 0:
        return np.zeros((0,)), np.zeros((0,))
    # the buffers are [max_iters]-padded; label only the recorded prefix,
    # rounded up to a bucket so differently-deep traces share jit caches
    m = min(mask.shape[0], -(-n_it // 64) * 64)
    params = jax.tree.map(lambda a: a[:m], trace.params)
    labels_hist = _trace_labels(jnp.asarray(x, jnp.float32), params,
                                algorithm)
    ref = (labels_hist[n_it - 1] if ref_labels is None
           else jnp.asarray(ref_labels, jnp.int32))
    r = np.asarray(_trace_rand(labels_hist, ref, k), np.float64)
    valid = (np.arange(m) < n_it) & np.isfinite(h[:m])
    return r[valid], h[:m][valid]


def reference_config(production: EngineConfig, algorithm: str,
                     max_iters: int | None = None) -> EngineConfig:
    """The full-batch reference regime for a production config: same
    memory layout and kernel routing, minibatch knobs reset, stop re-aimed
    at full convergence (frozen centroids / EM tolerance), no trace."""
    full = dataclasses.replace(
        production, mode="full", batch_chunks=0, decay=1.0, seed=0,
        ema=0.0, patience=1)
    cfg = harvest_config(full, algorithm, max_iters=max_iters)
    return dataclasses.replace(cfg, trace=False)


def reference_partition(plan: TrainingPlan, x, params0) -> jnp.ndarray:
    """One cheap offline full-batch fit → the [N] reference labels the
    matched harvest measures accuracy against."""
    cfg = reference_config(plan.config, plan.algorithm,
                           max_iters=plan.max_iters)
    eng = ClusteringEngine(plan.algorithm, cfg)
    return eng.fit(x, params0).labels


def harvest_traces(plan: TrainingPlan, groups,
                   mesh=None) -> list[tuple[np.ndarray, np.ndarray]]:
    """Run every training group under the plan's (harvest-adjusted)
    production config and return its (r, h) trace(s).

    ``mesh`` routes each fit through the engine's sharded drivers
    (``fit_sharded`` / ``fit_restarts_sharded``) — the trace is computed
    from psum'd stats, so it comes back replicated and identical to the
    single-device harvest up to fp32 reduction order.

    Minibatch harvests measure r against the group's *full-batch
    reference partition* (one cheap offline full-batch fit per group,
    seeded from the same init) — the trace's own subsample endpoint is
    not the partition production accuracy is validated against, and
    self-reference systematically inflated r (ROADMAP carry-over).
    Full-mode harvests run to the converged partition already, so their
    self-reference IS the full-batch reference and no extra fit runs.
    """
    out: list[tuple[np.ndarray, np.ndarray]] = []
    for gi in range(len(groups)):
        x = jnp.asarray(groups[gi], jnp.float32)
        cfg = harvest_config(
            plan.config, plan.algorithm, max_iters=plan.max_iters,
            seed=(plan.seed + gi
                  if plan.config.mode == "minibatch" else None))
        eng = ClusteringEngine(plan.algorithm, cfg)
        key = jax.random.PRNGKey(plan.seed + gi)
        needs_ref = plan.config.mode == "minibatch"
        if plan.restarts > 1:
            keys = jax.random.split(key, plan.restarts)
            inits = [_group_init(plan.algorithm, kk, x, plan.k, cfg.chunks)
                     for kk in keys]
            params0 = jax.tree.map(lambda *ls: jnp.stack(ls), *inits)
            ref = (reference_partition(plan, x, inits[0])
                   if needs_ref else None)
            rr = (eng.fit_restarts_sharded(x, params0, mesh)
                  if mesh is not None else eng.fit_restarts(x, params0))
            for ri in range(plan.restarts):
                tr = jax.tree.map(lambda a, ri=ri: a[ri], rr.traces)
                out.append(engine_trace_to_rh(
                    tr, x, algorithm=plan.algorithm, k=plan.k,
                    ref_labels=ref))
        else:
            params0 = _group_init(plan.algorithm, key, x, plan.k, cfg.chunks)
            ref = (reference_partition(plan, x, params0)
                   if needs_ref else None)
            res = (eng.fit_sharded(x, params0, mesh)
                   if mesh is not None else eng.fit(x, params0))
            out.append(engine_trace_to_rh(
                res.trace, x, algorithm=plan.algorithm, k=plan.k,
                ref_labels=ref))
    return out


def fit_for_config(plan: TrainingPlan, groups, mesh=None,
                   traces: Sequence[tuple[np.ndarray, np.ndarray]]
                   | None = None) -> LongTailModel:
    """Harvest (unless ``traces`` is supplied) and fit h = f(r) for the
    plan's engine configuration, stamping the regime into the model's
    provenance so ``EngineConfig.from_longtail`` can police the match."""
    if traces is None:
        traces = harvest_traces(plan, groups, mesh=mesh)
    return fit_longtail(
        traces, algorithm=plan.algorithm, dataset=plan.dataset,
        family=plan.family, balanced=plan.balanced,
        engine_config=config_fingerprint(
            plan.config, devices=(mesh.size if mesh is not None else 1)))
