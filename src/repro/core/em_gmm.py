"""EM for diagonal-covariance Gaussian mixtures — the paper's second algorithm.

The E-step log-density is decomposed into three [N,D]×[D,K] matmuls
(x²·(1/σ²)ᵀ, x·(μ/σ²)ᵀ and constants), so the hot loop is MXU-shaped like the
k-means assignment (DESIGN.md §2); the fused Pallas version lives in
``repro.kernels.gmm_estep``.  Objective = total log-likelihood, monotonically
increasing (Wu 1983), so Eq. 7's change rate applies unchanged.

Diagonal covariance is a documented assumption (DESIGN.md §6): the paper does
not specify the covariance structure; diagonal is the standard big-data
choice and keeps the E-step matmul-friendly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_LOG2PI = 1.8378770664093453


class GMMParams(NamedTuple):
    means: jnp.ndarray     # [K, D]
    var: jnp.ndarray       # [K, D] diagonal covariance
    log_w: jnp.ndarray     # [K] log mixture weights


VAR_FLOOR = 1e-6


def log_prob(x, params: GMMParams):
    """[N,K] per-component log densities via the matmul decomposition."""
    x = x.astype(jnp.float32)
    inv_var = 1.0 / params.var                                   # [K,D]
    # Σ_d (x−μ)²/σ² = x²·(1/σ²) − 2·x·(μ/σ²) + Σ_d μ²/σ²
    quad = ((x * x) @ inv_var.T
            - 2.0 * (x @ (params.means * inv_var).T)
            + jnp.sum(params.means ** 2 * inv_var, axis=-1)[None, :])
    log_det = jnp.sum(jnp.log(params.var), axis=-1)              # [K]
    d = x.shape[-1]
    return (params.log_w[None, :]
            - 0.5 * (quad + log_det[None, :] + d * _LOG2PI))


def estep_stats(x, params: GMMParams, axis_name=None, use_kernel: bool = False,
                mask=None, kernel_backend: str | None = None):
    """Fused E-step: responsibilities → (labels, loglik, r_sum, r_x, r_x2).

    All M-step sufficient statistics come out of one pass over the points —
    the same contract as the ``gmm_estep`` kernel op.  ``use_kernel``
    routes through the kernel dispatch layer (``repro.kernels.dispatch``;
    ``kernel_backend`` forces a registry backend).  ``mask``: [N] f32 row
    weights (streaming-chunk padding) — honoured by both paths.
    """
    if use_kernel:
        from repro.kernels.gmm_estep import ops as _gops
        labels, loglik, r_sum, r_x, r_x2 = _gops.gmm_estep(
            x, params.means, params.var, params.log_w, mask=mask,
            backend=kernel_backend)
    else:
        lp = log_prob(x, params)                                 # [N,K]
        lse = jax.scipy.special.logsumexp(lp, axis=-1)           # [N]
        resp = jnp.exp(lp - lse[:, None])                        # [N,K]
        labels = jnp.argmax(lp, axis=-1).astype(jnp.int32)
        if mask is not None:
            mask = mask.astype(jnp.float32)
            resp = resp * mask[:, None]
            loglik = jnp.sum(lse * mask)
            # weight-0 rows are labelled -1 — the kernel ops' mask contract
            labels = jnp.where(mask > 0, labels, -1)
        else:
            loglik = jnp.sum(lse)
        r_sum = jnp.sum(resp, axis=0)                            # [K]
        xf = x.astype(jnp.float32)
        r_x = resp.T @ xf                                        # [K,D]
        r_x2 = resp.T @ (xf * xf)                                # [K,D]
    if axis_name is not None:
        loglik = jax.lax.psum(loglik, axis_name)
        r_sum = jax.lax.psum(r_sum, axis_name)
        r_x = jax.lax.psum(r_x, axis_name)
        r_x2 = jax.lax.psum(r_x2, axis_name)
    return labels, loglik, r_sum, r_x, r_x2


def mstep(params: GMMParams, r_sum, r_x, r_x2, n_total) -> GMMParams:
    safe = jnp.maximum(r_sum, 1e-10)[:, None]
    means = r_x / safe
    var = jnp.maximum(r_x2 / safe - means ** 2, VAR_FLOOR)
    # Components with no support keep their old parameters (mirrors k-means
    # empty-cluster handling).
    alive = (r_sum > 1e-8)[:, None]
    means = jnp.where(alive, means, params.means)
    var = jnp.where(alive, var, params.var)
    log_w = jnp.log(jnp.maximum(r_sum / n_total, 1e-20))
    return GMMParams(means=means, var=var, log_w=log_w)


def minibatch_mstep(params: GMMParams, r_sum, r_x, r_x2, v, n_batch,
                    decay: float = 1.0):
    """Stepwise-EM M-step from subsampled responsibilities.

    Mirrors the k-means minibatch rule (see
    ``kmeans.minibatch_update_centroids``) with soft counts: ``v`` holds each
    component's cumulative responsibility mass, and the batch estimates are
    blended in with the per-component step size η_k = r_sum_k / v_k — the
    Robbins-Monro 1/t schedule of stepwise EM (Cappé & Moulines 2009), here
    annealed per component so rarely-responsible components are not dragged
    by large global steps.  ``decay`` < 1 forgets old mass exponentially;
    ``decay`` = 1 recovers the plain stochastic-approximation schedule.

    Sharded contract (shard_map): ``r_sum``/``r_x``/``r_x2`` and
    ``n_batch`` must arrive already psum'd over the data axes (the engine
    reduces shard-local E-step stats before the update), so ``v`` holds
    GLOBAL responsibility mass, η_k anneals on the global stream, the
    weight estimate ``r_sum / n_batch`` is the global batch fraction, and
    (params, v) stay replicated across shards with no extra collective.

    Returns (new_params, new_v).  Components with (numerically) zero batch
    responsibility keep their parameters, mirroring ``mstep``.
    """
    v_new = decay * v + r_sum
    eta = (r_sum / jnp.maximum(v_new, 1e-10))[:, None]           # [K, 1]
    safe = jnp.maximum(r_sum, 1e-10)[:, None]
    mu_b = r_x / safe
    var_b = jnp.maximum(r_x2 / safe - mu_b ** 2, VAR_FLOOR)
    alive = (r_sum > 1e-8)[:, None]
    means = jnp.where(alive, params.means + eta * (mu_b - params.means),
                      params.means)
    var = jnp.where(alive,
                    jnp.maximum(params.var + eta * (var_b - params.var),
                                VAR_FLOOR),
                    params.var)
    w_b = r_sum / jnp.maximum(n_batch, 1.0)                      # [K]
    w = jnp.exp(params.log_w)
    w = jnp.where(alive[:, 0], w + eta[:, 0] * (w_b - w), w)
    w = w / jnp.maximum(jnp.sum(w), 1e-20)
    return GMMParams(means=means, var=var,
                     log_w=jnp.log(jnp.maximum(w, 1e-20))), v_new


def em_step(x, params: GMMParams, n_total=None, axis_name=None,
            use_kernel: bool = False):
    """One EM iteration. Returns (new_params, labels, loglik)."""
    labels, loglik, r_sum, r_x, r_x2 = estep_stats(x, params, axis_name, use_kernel)
    if n_total is None:
        n_total = jnp.asarray(x.shape[0], jnp.float32)
        if axis_name is not None:
            n_total = jax.lax.psum(n_total, axis_name)
    return mstep(params, r_sum, r_x, r_x2, n_total), labels, loglik


def init_from_kmeans(x, centroids) -> GMMParams:
    """Means from k-means; shared isotropic variance; uniform weights."""
    k = centroids.shape[0]
    x = x.astype(jnp.float32)
    global_var = jnp.maximum(jnp.var(x, axis=0), VAR_FLOOR)
    return GMMParams(
        means=jnp.asarray(centroids, jnp.float32),
        var=jnp.broadcast_to(global_var, (k, x.shape[1])).astype(jnp.float32),
        log_w=jnp.full((k,), -jnp.log(k), jnp.float32),
    )


def random_init(key, x, k: int) -> GMMParams:
    from .kmeans import random_init as km_random
    return init_from_kmeans(x, km_random(key, x, k))


# --------------------------------------------------------------------------
# Drivers (mirror repro.core.kmeans)
# --------------------------------------------------------------------------

def em_fit_traced(x, params0: GMMParams, max_iters: int = 500,
                  tol: float = 0.0, use_kernel: bool = False,
                  chunks: int = 1):
    """Host loop recording (loglik_i, labels_i) — for training groups."""
    from .engine import ClusteringEngine, EngineConfig
    eng = ClusteringEngine("em", EngineConfig(use_kernel=use_kernel,
                                              chunks=chunks))
    params = params0
    x = jnp.asarray(x)
    labels_hist, js = [], []
    prev = None
    for _ in range(max_iters):
        params, labels, loglik = eng.step(x, params)
        labels_hist.append(labels)
        js.append(float(loglik))
        if prev is not None and abs(js[-1] - prev) <= tol * max(abs(prev), 1e-30):
            break
        prev = js[-1]
    return {
        "labels_history": jnp.stack(labels_hist),
        "objectives": jnp.asarray(js),
        "labels": labels_hist[-1],
        "params": params,
        "n_iters": len(js),
    }


def em_fit_earlystop(x, params0: GMMParams, h_star, max_iters: int = 500,
                     axis_name=None, use_kernel: bool = False,
                     patience: int = 1, chunks: int = 1):
    """Production driver: stop on device when h_i ≤ h* for ``patience``
    consecutive iterations (Eq. 7 on loglik; see kmeans_fit_earlystop)."""
    from .engine import ClusteringEngine, EngineConfig
    eng = ClusteringEngine("em", EngineConfig(
        max_iters=max_iters, patience=patience, chunks=chunks,
        axis_name=axis_name, use_kernel=use_kernel,
        use_h_stop=True, stop_when_frozen=False))
    res = eng.fit(x, params0, h_star=h_star)
    return res.params, res.labels, res.objective, res.n_iters


def em_fit_full(x, params0: GMMParams, max_iters: int = 1000, axis_name=None,
                use_kernel: bool = False, chunks: int = 1):
    """Reference run: converge to (near) machine-precision loglik stability."""
    return em_fit_earlystop(x, params0, h_star=1e-12, max_iters=max_iters,
                            axis_name=axis_name, use_kernel=use_kernel,
                            chunks=chunks)
