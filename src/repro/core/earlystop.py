"""The paper's contribution as a reusable control layer.

``LongTailModel`` packages the offline-trained regression:  set a desired
accuracy r*, get the change-rate threshold h* = f(r*) (the fitted Eq. 8
curve), and stop the iterative process the first time
h_i = |J_i − J_{i−1}|/|J_{i−1}| ≤ h*  (Eq. 7, §4).  The dollars that stop
saves are accounted by ``cost_model`` (Eq. 6/9/10); the provisioning
planner (``core.planner``) turns h* into a predicted stop iteration.

Two consumers:
  · the distributed clustering engine — the predicate runs **on device**
    inside ``jax.lax.while_loop`` (no host round-trip per iteration);
  · the LM training loop (beyond-paper generalisation) — ``EarlyStopHook``
    EMA-smooths the noisy SGD loss before applying the same rule.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .regression import RegressionModel, FitMetrics, select_model, pool_traces


def change_rate(j_curr, j_prev, eps: float = 1e-30):
    """h_i = |J_i − J_{i−1}| / |J_{i−1}|   (Eq. 7). Safe at J≈0."""
    return jnp.abs(j_curr - j_prev) / jnp.maximum(jnp.abs(j_prev), eps)


@dataclasses.dataclass(frozen=True)
class LongTailModel:
    """Fitted h(r) regression + provenance, serialisable for reuse (§5.4:

    the training process runs once; the regression is applied repeatedly).

    ``engine_config`` records the engine regime the (r, h) traces were
    harvested under (mode, batch_chunks, decay, ema, kernel routing, chunk
    layout, device count — see ``longtail_train.config_fingerprint``);
    ``EngineConfig.from_longtail`` compares it against the production
    config and warns loudly on a mismatch.  ``None`` marks a legacy /
    externally-harvested fit with no stamped regime (no warning)."""
    regression: RegressionModel
    algorithm: str                  # "kmeans" | "em" | "lm_train" | ...
    dataset: str
    n_train_groups: int
    comparison: dict | None = None  # {family: FitMetrics} from model selection
    engine_config: dict | None = None   # harvest-regime provenance

    def threshold_for(self, desired_accuracy: float) -> float:
        """h* = f(r*) — evaluate the fitted Eq. 8 regression at the
        desired accuracy (§4: the one number reused forever)."""
        return self.regression.threshold_for(desired_accuracy)

    # ---- persistence (tiny JSON artifacts, checkpointed with the run) ----
    def to_json(self) -> str:
        d = {
            "family": self.regression.family,
            "coeffs": list(self.regression.coeffs),
            "metrics": dataclasses.asdict(self.regression.metrics),
            "algorithm": self.algorithm,
            "dataset": self.dataset,
            "n_train_groups": self.n_train_groups,
        }
        if self.comparison is not None:
            d["comparison"] = {k: dataclasses.asdict(v) for k, v in self.comparison.items()}
        if self.engine_config is not None:
            d["engine_config"] = self.engine_config
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "LongTailModel":
        d = json.loads(s)
        reg = RegressionModel(family=d["family"], coeffs=tuple(d["coeffs"]),
                              metrics=FitMetrics(**d["metrics"]))
        comparison = None
        if "comparison" in d:
            comparison = {k: FitMetrics(**v) for k, v in d["comparison"].items()}
        return LongTailModel(regression=reg, algorithm=d["algorithm"],
                             dataset=d["dataset"],
                             n_train_groups=d["n_train_groups"],
                             comparison=comparison,
                             engine_config=d.get("engine_config"))


def fit_longtail(traces: Sequence[tuple[np.ndarray, np.ndarray]], *,
                 algorithm: str, dataset: str, family: str | None = None,
                 balanced: bool = False,
                 engine_config: dict | None = None) -> LongTailModel:
    """Pool (r, h) traces from the training groups and fit h = f(r) —
    the Eq. 8 regression (§4, training phase).

    ``family=None`` runs the paper's Eq. 8 model-selection comparison
    (linear/quadratic/exponential/…, lowest fit error wins) and keeps the
    winner; passing e.g. ``"quadratic"`` pins the paper's default.
    ``balanced=True`` applies the r-binned geometric-mean aggregation before
    fitting (beyond-paper robustification — see regression.balance_cloud).
    ``engine_config`` stamps harvest-regime provenance onto the model (see
    ``LongTailModel``); the mode-matched trainer always passes it.
    """
    r, h = pool_traces(traces)
    if balanced:
        from .regression import balance_cloud
        r, h = balance_cloud(r, h)
    if family is None:
        best, table = select_model(r, h)
    else:
        from .regression import fit_family
        best, table = fit_family(r, h, family), None
    return LongTailModel(regression=best, algorithm=algorithm, dataset=dataset,
                         n_train_groups=len(traces), comparison=table,
                         engine_config=engine_config)


def harvest_lm_trace(losses, ema: float = 0.95):
    """(r, h) pairs from a pilot run's loss curve, using EXACTLY the EMA
    recurrence EarlyStopHook applies online — so the fitted threshold lives
    on the same scale the hook will compare against.

    r_i = (s₀ − s_i) / (s₀ − s_final): relative progress of the smoothed
    objective toward its final value (the LM analogue of Rand accuracy).
    """
    losses = np.asarray(losses, np.float64)
    s = np.empty_like(losses)
    s[0] = losses[0]
    for i in range(1, losses.size):
        s[i] = ema * s[i - 1] + (1 - ema) * losses[i]
    # Eq. 7 anchored at J₀ instead of J_{i−1}: CE losses converge toward ~0,
    # where the relative-to-current rate stays constant under exponential
    # decay and never signals the tail.  Anchoring keeps h ↓ 0 as absolute
    # progress stalls (documented LM adaptation, DESIGN.md §2).
    h = np.abs(np.diff(s)) / max(abs(s[0]), 1e-30)
    denom = max(s[0] - s[-1], 1e-9)
    r = np.clip((s[0] - s[1:]) / denom, 0.0, 1.0)
    return r, h


class EarlyStopHook:
    """Host-side controller for noisy iterative objectives (LM training).

    SGD loss is not monotone per step, so the raw Eq. 7 signal is useless at
    step granularity.  We EMA both the objective and its change rate and
    require ``patience`` consecutive sub-threshold readings — a documented
    deviation from the paper (DESIGN.md §2), needed for the generalisation.
    """

    def __init__(self, model: LongTailModel, desired_accuracy: float,
                 ema: float = 0.98, patience: int = 5, min_steps: int = 20,
                 require_arming: bool = True):
        self.h_star = model.threshold_for(desired_accuracy)
        self.desired_accuracy = desired_accuracy
        self.ema = ema
        self.patience = patience
        self.min_steps = min_steps
        # arming: the h signal must first EXCEED h* (i.e. training must be
        # visibly improving) before sub-threshold readings count — prevents
        # spurious stops during the flat warmup phase where h starts near 0.
        self.require_arming = require_arming
        self._armed = not require_arming
        self._smoothed = None
        self._prev = None
        self._anchor = None   # J₀ — see harvest_lm_trace on why not J_{i−1}
        self._hits = 0
        self.step = 0
        self.history: list[tuple[int, float, float]] = []  # (step, J_ema, h)

    def update(self, objective: float) -> bool:
        """Feed one objective reading; returns True when training should stop."""
        self.step += 1
        obj = float(objective)
        self._smoothed = obj if self._smoothed is None else (
            self.ema * self._smoothed + (1 - self.ema) * obj)
        if self._prev is None:
            self._prev = self._smoothed
            self._anchor = max(abs(self._smoothed), 1e-30)
            return False
        h = abs(self._smoothed - self._prev) / self._anchor
        self._prev = self._smoothed
        self.history.append((self.step, self._smoothed, h))
        if not self._armed:
            self._armed = h > self.h_star
            return False
        if self.step < self.min_steps:
            return False
        self._hits = self._hits + 1 if h <= self.h_star else 0
        return self._hits >= self.patience
