"""Unified streaming clustering engine — one driver for k-means and EM.

The monolithic ``kmeans_fit_*`` / ``em_fit_*`` drivers each hand-rolled the
same while_loop + Eq. 7 predicate and required the whole [N, D] array (and a
materialised [N, K] distance/responsibility matrix) resident on one device.
This module folds them behind a small algorithm protocol
(``init / chunk_stats / update / objective``) and adds two scale axes:

  · **streaming assignment** — a ``lax.scan`` over [C, N/C, D] chunks
    accumulates the additive sufficient statistics ((sums, counts, J) for
    k-means; (r_sum, r_x, r_x2, loglik) for EM) so the [N, K] intermediate
    never exists for more than one chunk at a time; N is bounded by HBM
    streaming bandwidth rather than device memory.  The per-sweep result is
    bit-for-bit the same contract the Pallas kernels produce, and composes
    with the ``axis_name`` psum path (shard_map over the data axes): stats
    are accumulated locally, then psum'd once per sweep.

  · **multi-restart via ``vmap``** — R seeds run as one batched program.
    Each restart carries its own early-stop mask; once a restart trips the
    h_i ≤ h* predicate its state is frozen and the (still batched) body
    becomes a no-op for it.  The engine returns the best-objective restart —
    the standard production guard against bad initialisation.

Thresholds from an offline-fitted ``earlystop.LongTailModel`` enter through
``EngineConfig.from_longtail`` so the paper pipeline (fit h(r) once, reuse
h* = f(r*) forever) drives the same engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import em_gmm as _em
from . import kmeans as _km

_EPS = 1e-30


# --------------------------------------------------------------------------
# Algorithm protocol: init / chunk_stats / update / objective (+ kernels)
# --------------------------------------------------------------------------
# Implementations are stateless singletons; __eq__/__hash__ by type so they
# are stable jit static arguments across engine instances.

class KMeansAlgorithm:
    """Lloyd's k-means.  Params: centroids [K, D].  Stats: (sums, counts, J)."""

    name = "kmeans"
    maximize = False

    def __hash__(self):
        return hash(type(self).__name__)

    def __eq__(self, other):
        return type(other) is type(self)

    def init(self, key, x, k: int):
        return _km.kmeans_plus_plus_init(key, x, k)

    def zero_stats(self, params):
        k, d = params.shape
        return (jnp.zeros((k, d), jnp.float32), jnp.zeros((k,), jnp.float32),
                jnp.zeros((), jnp.float32))

    def chunk_stats(self, xc, mask, params):
        labels, sums, counts, j = _km.assign_and_stats(xc, params, mask=mask)
        return labels, (sums, counts, j)

    def kernel_stats(self, x, params, chunks: int):
        from repro.kernels.kmeans_assign import ops as _kops
        labels, sums, counts, j = _kops.kmeans_assign_chunked(
            x, params, chunks=chunks)
        return labels, (sums, counts, j)

    def update(self, params, stats, n_total):
        sums, counts, _ = stats
        return _km.update_centroids(params, sums, counts)

    def objective(self, stats):
        return stats[2]

    def moved(self, new_params, params):
        return jnp.any(new_params != params)


class EMAlgorithm:
    """Diagonal-covariance GMM via EM.  Params: GMMParams.
    Stats: (r_sum, r_x, r_x2, loglik)."""

    name = "em"
    maximize = True

    def __hash__(self):
        return hash(type(self).__name__)

    def __eq__(self, other):
        return type(other) is type(self)

    def init(self, key, x, k: int):
        return _em.random_init(key, x, k)

    def zero_stats(self, params):
        k, d = params.means.shape
        return (jnp.zeros((k,), jnp.float32), jnp.zeros((k, d), jnp.float32),
                jnp.zeros((k, d), jnp.float32), jnp.zeros((), jnp.float32))

    def chunk_stats(self, xc, mask, params):
        labels, loglik, r_sum, r_x, r_x2 = _em.estep_stats(
            xc, params, mask=mask)
        return labels, (r_sum, r_x, r_x2, loglik)

    def kernel_stats(self, x, params, chunks: int):
        from repro.kernels.gmm_estep import ops as _gops
        labels, loglik, r_sum, r_x, r_x2 = _gops.gmm_estep_chunked(
            x, params.means, params.var, params.log_w, chunks=chunks)
        return labels, (r_sum, r_x, r_x2, loglik)

    def update(self, params, stats, n_total):
        r_sum, r_x, r_x2, _ = stats
        return _em.mstep(params, r_sum, r_x, r_x2, n_total)

    def objective(self, stats):
        return stats[3]

    def moved(self, new_params, params):
        # EM has no frozen-partition fixed point at fp granularity; the
        # engine never gates EM on movement (stop_when_frozen=False).
        return jnp.asarray(True)


KMEANS = KMeansAlgorithm()
EM = EMAlgorithm()
_ALGORITHMS = {"kmeans": KMEANS, "em": EM}


def get_algorithm(algorithm):
    if isinstance(algorithm, str):
        return _ALGORITHMS[algorithm]
    return algorithm


# --------------------------------------------------------------------------
# Config + results
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static (hashable) engine configuration — one jit cache entry each.

    ``h_star`` here is the *default* threshold; ``fit`` accepts a traced
    override so sweeping thresholds does not retrace.
    """
    max_iters: int = 300
    h_star: float = 0.0
    patience: int = 1
    chunks: int = 1                 # C streaming chunks per sweep
    axis_name: Any = None           # psum stats over these mesh axes
    use_kernel: bool = False        # route sweeps through the Pallas kernels
    use_h_stop: bool = True         # apply the h_i <= h* long-tail predicate
    stop_when_frozen: bool = False  # stop when params stop moving (k-means)

    @classmethod
    def from_longtail(cls, model, desired_accuracy: float, **kw):
        """Route a fitted LongTailModel through the engine: h* = f(r*)."""
        return cls(h_star=float(model.threshold_for(desired_accuracy)), **kw)


class EngineResult(NamedTuple):
    params: Any                 # centroids [K,D] | GMMParams
    labels: jnp.ndarray         # [N] int32 (local rows under shard_map)
    objective: jnp.ndarray      # [] J / loglik at the final params
    n_iters: jnp.ndarray        # [] int32
    h: jnp.ndarray              # [] last change rate observed


class RestartResult(NamedTuple):
    best: EngineResult          # the argbest-objective restart
    best_index: jnp.ndarray     # [] int32
    objectives: jnp.ndarray     # [R] final objective per restart
    n_iters: jnp.ndarray        # [R] iterations per restart


# --------------------------------------------------------------------------
# Streaming sweep
# --------------------------------------------------------------------------

def _chunk_points(x, chunks: int):
    """[N, D] → ([C, ceil(N/C), D], mask [C, ceil(N/C)]) with zero-padding."""
    n, d = x.shape
    c = max(1, min(int(chunks), n))
    per = -(-n // c)
    pad = c * per - n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    mask = (jnp.arange(c * per) < n).astype(jnp.float32).reshape(c, per)
    return xp.reshape(c, per, d), mask


def _sweep(alg, config: EngineConfig, x, params, with_labels: bool):
    """One full pass over the points → (labels | None, sufficient stats).

    chunks=1 runs the monolithic fused pass; chunks>1 streams via lax.scan
    (pure-JAX path) or via the kernels' chunked entry points (fused path,
    static slices — each chunk keeps the kernel's own n_valid masking).
    Stats are psum'd over ``axis_name`` once per sweep.
    """
    if config.use_kernel:
        labels, stats = alg.kernel_stats(x, params, config.chunks)
        if not with_labels:
            labels = None
    elif config.chunks <= 1:
        ones = jnp.ones((x.shape[0],), jnp.float32)
        labels, stats = alg.chunk_stats(x, ones, params)
        if not with_labels:
            labels = None
    else:
        xc, mask = _chunk_points(x, config.chunks)

        def body(acc, inp):
            xi, mi = inp
            lab, st = alg.chunk_stats(xi, mi, params)
            acc = jax.tree.map(jnp.add, acc, st)
            return acc, (lab if with_labels else jnp.zeros((), jnp.int32))

        stats, labs = jax.lax.scan(body, alg.zero_stats(params), (xc, mask))
        labels = labs.reshape(-1)[: x.shape[0]] if with_labels else None
    if config.axis_name is not None:
        stats = jax.tree.map(
            lambda a: jax.lax.psum(a, config.axis_name), stats)
    return labels, stats


def _global_n(x, config: EngineConfig):
    n = jnp.asarray(x.shape[0], jnp.float32)
    if config.axis_name is not None:
        n = jax.lax.psum(n, config.axis_name)
    return n


# --------------------------------------------------------------------------
# Single-restart driver
# --------------------------------------------------------------------------

class _State(NamedTuple):
    params: Any
    j_curr: jnp.ndarray
    h: jnp.ndarray
    hits: jnp.ndarray
    iteration: jnp.ndarray
    moved: jnp.ndarray


def _live(config: EngineConfig, iteration, hits, moved):
    """Continue-predicate shared by cond() and the per-restart masks."""
    live = iteration < config.max_iters
    if config.use_h_stop:
        live = jnp.logical_and(
            live, jnp.logical_or(iteration < 2, hits < config.patience))
    if config.stop_when_frozen:
        live = jnp.logical_and(live, moved)
    return live


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _fit(x, params0, h_star, alg, config: EngineConfig):
    x = x.astype(jnp.float32)
    n_total = _global_n(x, config)
    init = _State(
        params=jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0),
        j_curr=jnp.asarray(jnp.inf, jnp.float32),
        h=jnp.asarray(jnp.inf, jnp.float32),
        hits=jnp.asarray(0, jnp.int32),
        iteration=jnp.asarray(0, jnp.int32),
        moved=jnp.asarray(True),
    )

    def cond(s: _State):
        return _live(config, s.iteration, s.hits, s.moved)

    def body(s: _State):
        _, stats = _sweep(alg, config, x, s.params, with_labels=False)
        j = alg.objective(stats)
        new_params = alg.update(s.params, stats, n_total)
        h = jnp.where(
            jnp.isfinite(s.j_curr),
            jnp.abs(j - s.j_curr) / jnp.maximum(jnp.abs(s.j_curr), _EPS),
            jnp.asarray(jnp.inf, jnp.float32))
        hits = jnp.where(h <= h_star, s.hits + 1, 0)
        moved = alg.moved(new_params, s.params)
        return _State(new_params, j, h, hits, s.iteration + 1, moved)

    final = jax.lax.while_loop(cond, body, init)
    labels, stats = _sweep(alg, config, x, final.params, with_labels=True)
    return EngineResult(final.params, labels, alg.objective(stats),
                        final.iteration, final.h)


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _step(x, params, alg, config: EngineConfig):
    """One iteration: (new_params, labels, objective) — the traced drivers'
    building block, so host-loop and on-device paths share one sweep."""
    x = x.astype(jnp.float32)
    n_total = _global_n(x, config)
    labels, stats = _sweep(alg, config, x, params, with_labels=True)
    return alg.update(params, stats, n_total), labels, alg.objective(stats)


# --------------------------------------------------------------------------
# Multi-restart driver (vmap + per-restart stop masks)
# --------------------------------------------------------------------------

class _BatchState(NamedTuple):
    params: Any                 # [R, ...]
    j_curr: jnp.ndarray         # [R]
    h: jnp.ndarray              # [R]
    hits: jnp.ndarray           # [R] int32
    n_iters: jnp.ndarray        # [R] int32
    moved: jnp.ndarray          # [R] bool
    active: jnp.ndarray         # [R] bool — restart still iterating


def _mask_tree(active, new, old):
    """Per-leaf jnp.where with `active` broadcast over trailing dims."""
    def one(n, o):
        a = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)
    return jax.tree.map(one, new, old)


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _fit_restarts(x, params0, h_star, alg, config: EngineConfig):
    x = x.astype(jnp.float32)
    n_total = _global_n(x, config)
    r = jax.tree.leaves(params0)[0].shape[0]

    sweep_stats = jax.vmap(
        lambda p: _sweep(alg, config, x, p, with_labels=False)[1])
    sweep_labels = jax.vmap(
        lambda p: _sweep(alg, config, x, p, with_labels=True))
    update_v = jax.vmap(alg.update, in_axes=(0, 0, None))
    objective_v = jax.vmap(alg.objective)
    moved_v = jax.vmap(alg.moved)

    inf = jnp.full((r,), jnp.inf, jnp.float32)
    zeros_i = jnp.zeros((r,), jnp.int32)
    true_b = jnp.ones((r,), bool)
    init = _BatchState(
        params=jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0),
        j_curr=inf, h=inf, hits=zeros_i, n_iters=zeros_i,
        moved=true_b, active=_live(config, zeros_i, zeros_i, true_b),
    )

    def cond(s: _BatchState):
        return jnp.any(s.active)

    def body(s: _BatchState):
        # every restart computes; stopped restarts are masked back to their
        # frozen state (the "no-op body" — XLA keeps one batched program)
        stats = sweep_stats(s.params)
        j = objective_v(stats)
        new_params = update_v(s.params, stats, n_total)
        h = jnp.where(
            jnp.isfinite(s.j_curr),
            jnp.abs(j - s.j_curr) / jnp.maximum(jnp.abs(s.j_curr), _EPS),
            jnp.inf).astype(jnp.float32)
        hits = jnp.where(h <= h_star, s.hits + 1, 0)
        moved = moved_v(new_params, s.params)
        a = s.active
        params = _mask_tree(a, new_params, s.params)
        j_curr = jnp.where(a, j, s.j_curr)
        h_out = jnp.where(a, h, s.h)
        hits_out = jnp.where(a, hits, s.hits)
        n_iters = jnp.where(a, s.n_iters + 1, s.n_iters)
        moved_out = jnp.where(a, moved, s.moved)
        active = jnp.logical_and(
            a, _live(config, n_iters, hits_out, moved_out))
        return _BatchState(params, j_curr, h_out, hits_out, n_iters,
                           moved_out, active)

    final = jax.lax.while_loop(cond, body, init)
    labels, stats = sweep_labels(final.params)
    objectives = objective_v(stats)
    best = (jnp.argmax(objectives) if alg.maximize
            else jnp.argmin(objectives)).astype(jnp.int32)
    best_result = EngineResult(
        params=jax.tree.map(lambda a: a[best], final.params),
        labels=labels[best],
        objective=objectives[best],
        n_iters=final.n_iters[best],
        h=final.h[best],
    )
    return RestartResult(best=best_result, best_index=best,
                         objectives=objectives, n_iters=final.n_iters)


# --------------------------------------------------------------------------
# Public facade
# --------------------------------------------------------------------------

class ClusteringEngine:
    """One engine, two algorithms, three drivers (step / fit / fit_restarts).

    >>> eng = ClusteringEngine("kmeans", EngineConfig(chunks=8, max_iters=100,
    ...                                               stop_when_frozen=True))
    >>> res = eng.fit(x, eng.init(key, x, k=8), h_star=1e-4)
    >>> best = eng.fit_restarts(x, key=key, k=8, restarts=4).best
    """

    def __init__(self, algorithm="kmeans", config: EngineConfig | None = None):
        self.algorithm = get_algorithm(algorithm)
        self.config = config if config is not None else EngineConfig()

    # -- initialisation ----------------------------------------------------
    def init(self, key, x, k: int):
        return self.algorithm.init(key, jnp.asarray(x), k)

    def init_restarts(self, key, x, k: int, restarts: int):
        """R independent seeds, stacked along a leading restart axis."""
        x = jnp.asarray(x)
        keys = jax.random.split(key, restarts)
        inits = [self.algorithm.init(kk, x, k) for kk in keys]
        return jax.tree.map(lambda *leaves: jnp.stack(leaves), *inits)

    # -- drivers -----------------------------------------------------------
    def step(self, x, params):
        """One iteration → (new_params, labels, objective)."""
        return _step(jnp.asarray(x), params, self.algorithm, self.config)

    def fit(self, x, params0, h_star=None) -> EngineResult:
        hs = self.config.h_star if h_star is None else h_star
        return _fit(jnp.asarray(x), params0, jnp.asarray(hs, jnp.float32),
                    self.algorithm, self.config)

    def fit_restarts(self, x, params0=None, *, key=None, k=None,
                     restarts=None, h_star=None) -> RestartResult:
        """Batched multi-restart fit; pass stacked ``params0`` or
        (key, k, restarts) to draw them."""
        x = jnp.asarray(x)
        if params0 is None:
            if key is None or k is None or restarts is None:
                raise ValueError(
                    "fit_restarts needs params0 or (key, k, restarts)")
            params0 = self.init_restarts(key, x, k, restarts)
        if self.config.use_kernel:
            raise NotImplementedError(
                "multi-restart vmap over the Pallas kernels is not wired up; "
                "use use_kernel=False for fit_restarts")
        hs = self.config.h_star if h_star is None else h_star
        return _fit_restarts(x, params0, jnp.asarray(hs, jnp.float32),
                             self.algorithm, self.config)
