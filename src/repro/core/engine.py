"""Unified streaming clustering engine — one driver for k-means and EM.

The monolithic ``kmeans_fit_*`` / ``em_fit_*`` drivers each hand-rolled the
same while_loop + Eq. 7 predicate and required the whole [N, D] array (and a
materialised [N, K] distance/responsibility matrix) resident on one device.
This module folds them behind a small algorithm protocol
(``init / chunk_stats / update / objective``) and adds two scale axes:

  · **streaming assignment** — a ``lax.scan`` over [C, N/C, D] chunks
    accumulates the additive sufficient statistics ((sums, counts, J) for
    k-means; (r_sum, r_x, r_x2, loglik) for EM) so the [N, K] intermediate
    never exists for more than one chunk at a time; N is bounded by HBM
    streaming bandwidth rather than device memory.  The per-sweep result is
    bit-for-bit the same contract the Pallas kernels produce, and composes
    with the ``axis_name`` psum path (shard_map over the data axes): stats
    are accumulated locally, then psum'd once per sweep.

  · **multi-restart via ``vmap``** — R seeds run as one batched program.
    Each restart carries its own early-stop mask; once a restart trips the
    h_i ≤ h* predicate its state is frozen and the (still batched) body
    becomes a no-op for it.  The engine returns the best-objective restart —
    the standard production guard against bad initialisation.

  · **minibatch mode** — ``EngineConfig(mode="minibatch", chunks=C,
    batch_chunks=B)`` makes every iteration sample B of the C chunks
    (without replacement, fresh draw per step) and apply learning-rate
    parameter updates: Sculley-style per-cluster counts for k-means,
    stepwise-EM responsibility mass for GMMs (see
    ``kmeans.minibatch_update_centroids`` / ``em_gmm.minibatch_mstep`` for
    the 1/t schedules and the ``decay`` forgetting factor).  Per-iteration
    data touch drops from N to N·B/C, which is the regime the paper's
    cost argument needs at scales where even one full sweep is expensive.
    The Eq. 7 change rate h is *paired*: the same subsample is evaluated
    at the old and at the new parameters, so the sampling noise cancels in
    the ratio and a full-batch fitted h* = f(r*) transfers to minibatch
    stopping (raw cross-batch differences would floor h at the subsample
    noise, ~1/√batch).  The pairing costs a second distance pass over the
    subsample — 2·B/C of a full sweep's compute, still B/C distinct data.
    ``patience`` > 1 still robustifies against lucky draws, and ``ema``
    optionally smooths h.  The final labels pass is always a full sweep,
    so the result contract is unchanged.

All three axes compose with ``use_kernel=True`` (ISSUE 4): sweeps route
through the backend-dispatched kernel ops (``repro.kernels.dispatch`` —
tpu/gpu Pallas, interpreter elsewhere, or the ``xla`` reference;
``kernel_backend`` pins one).  Multi-restart rides the kernels' restart
grid axis via their ``custom_vmap`` rules, minibatch uses the gather-free
statically-sliced subsample driver, and the sharded drivers run the masked
chunk layout through the same per-chunk kernel calls.

Thresholds from an offline-fitted ``earlystop.LongTailModel`` enter through
``EngineConfig.from_longtail`` so the paper pipeline (fit h(r) once, reuse
h* = f(r*) forever) drives the same engine.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import em_gmm as _em
from . import kmeans as _km

_EPS = 1e-30


class ProvenanceMismatchError(ValueError):
    """A fitted ``LongTailModel`` is being routed into an engine regime that
    does not match the configuration its (r, h) traces were harvested under.

    Raised by ``EngineConfig.from_longtail(..., strict=True)`` — the serving
    registry's admission path — instead of the advisory ``UserWarning`` the
    non-strict research path emits.  ``diff`` maps each mismatched field to
    ``(fitted, production)``."""

    def __init__(self, message: str, diff: dict):
        super().__init__(message)
        self.diff = diff


# --------------------------------------------------------------------------
# Algorithm protocol: init / chunk_stats / update / objective (+ kernels)
# --------------------------------------------------------------------------
# Implementations are stateless singletons; __eq__/__hash__ by type so they
# are stable jit static arguments across engine instances.

class KMeansAlgorithm:
    """Lloyd's k-means.  Params: centroids [K, D].  Stats: (sums, counts, J)."""

    name = "kmeans"
    maximize = False

    def __hash__(self):
        return hash(type(self).__name__)

    def __eq__(self, other):
        return type(other) is type(self)

    def init(self, key, x, k: int, chunks: int = 1):
        return _km.kmeans_plus_plus_init(key, x, k, chunks=chunks)

    def zero_stats(self, params):
        k, d = params.shape
        return (jnp.zeros((k, d), jnp.float32), jnp.zeros((k,), jnp.float32),
                jnp.zeros((), jnp.float32))

    def zero_carry(self, params):
        """Minibatch carry: cumulative per-cluster counts v [K]."""
        return jnp.zeros((params.shape[0],), jnp.float32)

    def minibatch_update(self, params, stats, carry, n_batch, decay):
        del n_batch  # EWA uses decay directly; EM's leg needs the count
        sums, counts, _ = stats
        return _km.minibatch_update_centroids(params, sums, counts, carry,
                                              decay)

    def chunk_stats(self, xc, mask, params):
        labels, sums, counts, j = _km.assign_and_stats(xc, params, mask=mask)
        return labels, (sums, counts, j)

    def kernel_stats(self, x, params, chunks: int, backend=None):
        from repro.kernels.kmeans_assign import ops as _kops
        labels, sums, counts, j = _kops.kmeans_assign_chunked(
            x, params, chunks=chunks, backend=backend)
        return labels, (sums, counts, j)

    def kernel_chunk_stats(self, xc, mask, params, backend=None):
        """One masked chunk through the dispatched kernel op — the fused
        counterpart of ``chunk_stats`` (same contract)."""
        from repro.kernels.kmeans_assign import ops as _kops
        labels, sums, counts, j = _kops.kmeans_assign(
            xc, params, mask=mask, backend=backend)
        return labels, (sums, counts, j)

    def update(self, params, stats, n_total):
        del n_total  # centroid means normalise by per-cluster counts
        sums, counts, _ = stats
        return _km.update_centroids(params, sums, counts)

    def objective(self, stats):
        return stats[2]

    def moved(self, new_params, params):
        return jnp.any(new_params != params)

    # centred compression basis (see _stats_reducer): transmit
    # Σ(x − c_prev) per cluster instead of Σx.  The centred sums are
    # count·(cluster mean − current centroid) — they shrink as the fit
    # converges, and the int8 ring's pmax-shared scale shrinks with them,
    # so quantisation error decays with the residual motion instead of
    # staying pinned at ~1% of the raw moment magnitude.  The transform is
    # linear per shard, so it commutes with the cross-shard sum and inverts
    # exactly from the reduced (counts, centred sums).
    def compress_basis(self, params, stats):
        sums, counts, j = stats
        return (sums - counts[:, None] * params, counts, j)

    def decompress_basis(self, params, stats):
        csums, counts, j = stats
        return (csums + counts[:, None] * params, counts, j)


class EMAlgorithm:
    """Diagonal-covariance GMM via EM.  Params: GMMParams.
    Stats: (r_sum, r_x, r_x2, loglik)."""

    name = "em"
    maximize = True

    def __hash__(self):
        return hash(type(self).__name__)

    def __eq__(self, other):
        return type(other) is type(self)

    def init(self, key, x, k: int, chunks: int = 1):
        del chunks  # uniform draw touches k rows, nothing to stream
        return _em.random_init(key, x, k)

    def zero_stats(self, params):
        k, d = params.means.shape
        return (jnp.zeros((k,), jnp.float32), jnp.zeros((k, d), jnp.float32),
                jnp.zeros((k, d), jnp.float32), jnp.zeros((), jnp.float32))

    def zero_carry(self, params):
        """Minibatch carry: cumulative responsibility mass v [K]."""
        return jnp.zeros((params.means.shape[0],), jnp.float32)

    def minibatch_update(self, params, stats, carry, n_batch, decay):
        r_sum, r_x, r_x2, _ = stats
        return _em.minibatch_mstep(params, r_sum, r_x, r_x2, carry, n_batch,
                                   decay)

    def chunk_stats(self, xc, mask, params):
        labels, loglik, r_sum, r_x, r_x2 = _em.estep_stats(
            xc, params, mask=mask)
        return labels, (r_sum, r_x, r_x2, loglik)

    def kernel_stats(self, x, params, chunks: int, backend=None):
        from repro.kernels.gmm_estep import ops as _gops
        labels, loglik, r_sum, r_x, r_x2 = _gops.gmm_estep_chunked(
            x, params.means, params.var, params.log_w, chunks=chunks,
            backend=backend)
        return labels, (r_sum, r_x, r_x2, loglik)

    def kernel_chunk_stats(self, xc, mask, params, backend=None):
        """One masked chunk through the dispatched kernel op — the fused
        counterpart of ``chunk_stats`` (same contract)."""
        from repro.kernels.gmm_estep import ops as _gops
        labels, loglik, r_sum, r_x, r_x2 = _gops.gmm_estep(
            xc, params.means, params.var, params.log_w, mask=mask,
            backend=backend)
        return labels, (r_sum, r_x, r_x2, loglik)

    def update(self, params, stats, n_total):
        r_sum, r_x, r_x2, _ = stats
        return _em.mstep(params, r_sum, r_x, r_x2, n_total)

    def objective(self, stats):
        return stats[3]

    # centred compression basis (see _stats_reducer and the k-means
    # counterpart).  EM *requires* this: the M-step variance is
    # r_x2/r_sum − mean², a catastrophic cancellation — with means ~9 and
    # var ~1 the raw second moment is ~82 while the variance is 1, so a 1%
    # int8 error on r_x2 is an ~80% error on var and EM diverges.  Centred
    # moments Σr(x−μ) and Σr(x−μ)² are the same magnitude as the answers
    # they produce, so quantisation error stays proportional.  Both
    # transforms are linear in (r_sum, r_x, r_x2) per shard, commute with
    # the cross-shard sum, and invert exactly from the reduced tree.
    def compress_basis(self, params, stats):
        r_sum, r_x, r_x2, ll = stats
        a = params.means
        r_xc = r_x - r_sum[:, None] * a
        r_x2c = r_x2 - 2.0 * a * r_x + (a * a) * r_sum[:, None]
        return (r_sum, r_xc, r_x2c, ll)

    def decompress_basis(self, params, stats):
        r_sum, r_xc, r_x2c, ll = stats
        a = params.means
        r_x = r_xc + r_sum[:, None] * a
        r_x2 = r_x2c + 2.0 * a * r_x - (a * a) * r_sum[:, None]
        return (r_sum, r_x, r_x2, ll)

    def moved(self, new_params, params):
        # EM has no frozen-partition fixed point at fp granularity; the
        # engine never gates EM on movement (stop_when_frozen=False).
        del new_params, params
        return jnp.asarray(True)


KMEANS = KMeansAlgorithm()
EM = EMAlgorithm()
_ALGORITHMS = {"kmeans": KMEANS, "em": EM}


def get_algorithm(algorithm):
    if isinstance(algorithm, str):
        return _ALGORITHMS[algorithm]
    return algorithm


# --------------------------------------------------------------------------
# Config + results
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static (hashable) engine configuration — one jit cache entry each.

    ``h_star`` here is the *default* threshold; ``fit`` accepts a traced
    override so sweeping thresholds does not retrace.

    ``use_kernel`` routes every sweep (full, chunked, minibatch, restarts,
    sharded) through the backend-dispatched kernel ops;
    ``kernel_backend`` pins a registry backend ("tpu" / "gpu" /
    "interpret" / "xla" or a custom ``register_backend`` name — see
    ``repro.kernels.dispatch``).  ``None``/"auto" resolve to the
    platform's default backend *at construction* (honouring an active
    ``dispatch.force_backend``), so the concrete name is part of this
    static config and jit caches never cross backends.  The
    ``REPRO_FORCE_KERNEL_BACKEND`` env var reroutes every config through
    the kernel path (the CI coverage hook; explicitly pinned backends
    win).

    ``mode="minibatch"`` samples ``batch_chunks`` of the ``chunks`` pieces
    per iteration and applies learning-rate updates with forgetting factor
    ``decay`` (1.0 = pure 1/t annealing; see the module docstring).  The
    chunk draw is seeded from ``seed`` so runs are reproducible; under
    ``axis_name`` every shard draws the same chunk indices from its local
    chunking and the psum'd stats + batch count keep the update and the
    stop decision globally agreed.  The sharded drivers
    (``ClusteringEngine.fit_sharded`` / ``fit_restarts_sharded``) make the
    local chunking a row-slice of the *global* one, so the drawn subsample
    — and hence the whole trajectory — matches the single-device run up to
    fp32 reduction order.

    ``trace=True`` makes every fit driver additionally return a
    per-iteration :class:`Trace` (objective sequence, Eq. 7 change-rate
    sequence — the *paired* rate in minibatch mode — iteration mask and
    the parameter trajectory) recorded inside the ``while_loop`` carry.
    This is the mode-matched training hook: (r, h) harvesting runs under
    the exact production configuration instead of replaying sweeps
    host-side (see ``repro.core.longtail_train``).  The buffers are
    [max_iters]-shaped (params: [max_iters, ...]); sizes are a few KB for
    clustering workloads.

    ``stats_compression="int8_ef"`` routes every per-sweep stats reduction
    in the sharded drivers through the int8 ring all-reduce with error
    feedback (``repro.distribution.compression``) instead of fp32 psum:
    array-valued sufficient statistics (centroid sums, counts, GMM
    moments) move over the wire as int8 chunks (~4× fewer collective
    bytes), the quantisation residual is carried in the fit loop's
    ``while_loop`` state (per restart under vmap), and the scalar
    objective leaves (J / loglik) stay exact fp32 psum — int8's ~8e-3
    relative resolution would destroy the Eq. 7 stop they drive.
    ``stats_axis_size`` is the ring's static size; the sharded drivers
    resolve it from the mesh, so normal use is just
    ``EngineConfig(stats_compression="int8_ef")`` + ``fit_sharded``.  The
    final labels/objective pass always reduces exact, so the result
    contract is unchanged; only the trajectory sees quantisation (parity
    on stop iterations is gated in ``BENCH_sharded_overlap.json``).

    ``prefetch=True`` double-buffers the streaming chunk scan: the scan
    carry holds the chunk being processed while the body issues the
    dynamic-slice load of chunk i+1, so the next chunk's copy has no data
    dependency on the current chunk's matmul and the scheduler can overlap
    them.  Chunk order and accumulation math are unchanged — results are
    bit-identical to the synchronous scan.

    ``autotune=True`` (requires ``use_kernel=True``) resolves kernel
    block shapes from the autotuner's winner cache
    (``repro.kernels.autotune``): every fit driver runs inside an
    ``autotune.tuning(autotune.default_cache())`` scope, so the
    dispatched ops consult the cache keyed by (op, backend, device kind,
    shape bucket).  No cache installed (``set_default_cache`` /
    ``REPRO_AUTOTUNE_CACHE``) → the hand-picked ``TilePolicy`` defaults,
    bit-for-bit.  The flag is part of this static config, so tuned and
    untuned fits never share a trace; swapping caches mid-process needs
    ``jax.clear_caches()``.  Tuned blocks regroup fp32 accumulation but
    compute the same update, so stop iterations match the untuned run
    (gated in CI's autotune-smoke job).
    """
    max_iters: int = 300
    h_star: float = 0.0
    patience: int = 1
    chunks: int = 1                 # C streaming chunks per sweep
    axis_name: Any = None           # psum stats over these mesh axes
    use_kernel: bool = False        # route sweeps through the kernel ops
    use_h_stop: bool = True         # apply the h_i <= h* long-tail predicate
    stop_when_frozen: bool = False  # stop when params stop moving (k-means)
    mode: str = "full"              # "full" | "minibatch"
    batch_chunks: int = 0           # B of C chunks sampled per minibatch step
    decay: float = 1.0              # minibatch count forgetting factor
    seed: int = 0                   # minibatch chunk-sampling PRNG stream
    ema: float = 0.0                # minibatch h smoothing (0 = raw)
    kernel_backend: str | None = None   # registry backend; None = auto
    trace: bool = False             # record a per-iteration Trace
    stats_compression: str = "none"     # "none" | "int8_ef" sweep reductions
    stats_axis_size: int = 0        # ring size; sharded drivers resolve it
    prefetch: bool = False          # double-buffer the streaming chunk scan
    autotune: bool = False          # kernel blocks from the autotune cache

    def __post_init__(self):
        # CI hook: REPRO_FORCE_KERNEL_BACKEND=<backend> reroutes every
        # engine config through the kernel dispatch layer, so the whole
        # engine suite doubles as kernel-path coverage.  An explicitly
        # pinned kernel_backend wins over the env (backend-vs-backend
        # parity tests keep comparing what they name).
        forced = os.environ.get("REPRO_FORCE_KERNEL_BACKEND")
        if forced:
            if not self.use_kernel:
                object.__setattr__(self, "use_kernel", True)
            if self.kernel_backend in (None, "auto"):
                object.__setattr__(self, "kernel_backend", forced)
        if self.mode not in ("full", "minibatch"):
            raise ValueError(f"unknown engine mode {self.mode!r}")
        if not 0.0 <= self.ema < 1.0:
            raise ValueError(f"ema must be in [0, 1); got {self.ema}")
        if self.kernel_backend is not None and not self.use_kernel:
            raise ValueError(
                "kernel_backend has no effect with use_kernel=False — "
                "pass use_kernel=True (CLI: --use-kernel) or drop it")
        if self.autotune and not self.use_kernel:
            raise ValueError(
                "autotune=True resolves kernel block shapes, but "
                "use_kernel=False never dispatches a kernel — pass "
                "use_kernel=True (CLI: --use-kernel) or drop it")
        if self.use_kernel and self.kernel_backend in (None, "auto"):
            # resolve eagerly: the concrete backend becomes part of this
            # static (hashable) config, so the jit caches keyed on it can
            # never reuse a trace from another backend (including under a
            # dispatch.force_backend() active right now).  Names the
            # registry does not know fail at the first op dispatch with
            # the available list — custom register_backend() names are
            # legal here.
            from repro.kernels import dispatch as _dispatch
            object.__setattr__(self, "kernel_backend",
                               _dispatch.default_backend())
        if self.mode == "full":
            stray = [f"{name}={value!r}" for name, value, default in (
                ("batch_chunks", self.batch_chunks, 0),
                ("decay", self.decay, 1.0),
                ("seed", self.seed, 0),
                ("ema", self.ema, 0.0)) if value != default]
            if stray:
                raise ValueError(
                    "minibatch-only settings " + ", ".join(stray) +
                    " have no effect in mode='full' — pass mode='minibatch' "
                    "(CLI: --mode minibatch) or drop them")
        if self.mode == "minibatch":
            if self.chunks < 2:
                raise ValueError(
                    "minibatch mode needs chunks >= 2 (the sweep samples "
                    "batch_chunks of them); got chunks="
                    f"{self.chunks}")
            if not 1 <= self.batch_chunks < self.chunks:
                raise ValueError(
                    "minibatch mode needs 1 <= batch_chunks < chunks; got "
                    f"batch_chunks={self.batch_chunks}, chunks={self.chunks}")
            if not 0.0 < self.decay <= 1.0:
                raise ValueError(f"decay must be in (0, 1]; got {self.decay}")
        if self.stats_compression not in ("none", "int8_ef"):
            raise ValueError(
                f"unknown stats_compression {self.stats_compression!r}; "
                "choose 'none' (fp32 psum) or 'int8_ef' (int8 ring "
                "all-reduce with error feedback)")
        if self.stats_axis_size < 0:
            raise ValueError(
                f"stats_axis_size must be >= 0; got {self.stats_axis_size}")
        if self.stats_compression == "none" and self.stats_axis_size:
            raise ValueError(
                f"stats_axis_size={self.stats_axis_size} has no effect with "
                "stats_compression='none' — pass "
                "stats_compression='int8_ef' or drop it")
        if self.stats_compression != "none":
            if self.stop_when_frozen:
                raise ValueError(
                    "stop_when_frozen requires bit-exact parameter fixed "
                    "points, which int8-quantised stats never reach (the "
                    "centroids keep jittering at quantisation granularity "
                    "and the fit only ends at max_iters) — use the Eq. 7 "
                    "h stop with stats_compression='int8_ef'")
            if isinstance(self.axis_name, tuple):
                raise ValueError(
                    "stats_compression rides a single-axis ppermute ring; "
                    f"axis_name={self.axis_name!r} names "
                    f"{len(self.axis_name)} mesh axes — collapse the data "
                    "axes into one or use stats_compression='none'")
            if self.axis_name is not None and self.stats_axis_size < 1:
                raise ValueError(
                    "stats_compression='int8_ef' with an explicit "
                    f"axis_name={self.axis_name!r} needs stats_axis_size "
                    "(the ring's static size); the sharded drivers "
                    "(fit_sharded / fit_restarts_sharded) resolve it from "
                    "the mesh automatically")

    # engine-regime fields a fitted LongTailModel's provenance is compared
    # against in from_longtail (chunks only matters when minibatch draws
    # sample from it — full-mode chunking is a memory layout, not a regime)
    MATCHED_FIELDS = ("mode", "batch_chunks", "decay", "ema", "use_kernel",
                      "kernel_backend")

    def matched_fingerprint(self) -> dict:
        """The regime this config clusters under, as stampable provenance."""
        d = {f: getattr(self, f) for f in self.MATCHED_FIELDS}
        d["chunks"] = self.chunks
        return d

    @classmethod
    def from_longtail(cls, model, desired_accuracy: float,
                      strict: bool = False, **kw):
        """Route a fitted LongTailModel through the engine: h* = f(r*).

        When the model carries engine-config provenance (it was fitted by
        ``repro.core.longtail_train`` on traces harvested under a concrete
        ``EngineConfig``), the production config built here is compared
        against it.  A regime mismatch fires a loud ``UserWarning`` — a
        transferred h* still *works* (the paired stop keeps the Eq. 7
        scale compatible) but is not mode-matched, which widens the
        achieved-accuracy spread (ROADMAP; ``BENCH_longtail_matched.json``
        quantifies it).  ``strict=True`` upgrades the warning to
        :class:`ProvenanceMismatchError` — the serving registry's admission
        contract, where a silently mis-calibrated threshold must never
        reach production traffic.
        """
        cfg = cls(h_star=float(model.threshold_for(desired_accuracy)), **kw)
        prov = getattr(model, "engine_config", None)
        if prov:
            fields = list(cls.MATCHED_FIELDS)
            if prov.get("mode") == "minibatch" or cfg.mode == "minibatch":
                fields.append("chunks")
            diff = {f: (prov[f], getattr(cfg, f)) for f in fields
                    if f in prov and prov[f] != getattr(cfg, f)}
            if diff:
                detail = ", ".join(f"{f}: fitted={a!r} production={b!r}"
                                   for f, (a, b) in sorted(diff.items()))
                msg = (
                    "LongTailModel was fitted under a different engine "
                    f"configuration than it is now serving ({detail}); "
                    "h* transfers via the paired Eq. 7 stop but is not "
                    "mode-matched — re-fit with "
                    "repro.core.longtail_train.fit_for_config under the "
                    "production EngineConfig to tighten the achieved-"
                    "accuracy spread")
                if strict:
                    raise ProvenanceMismatchError(msg, diff)
                import warnings
                warnings.warn(msg, UserWarning, stacklevel=2)
        return cfg


class Trace(NamedTuple):
    """Per-iteration fit history, recorded on device when ``config.trace``.

    All buffers are [T] = [max_iters]-shaped ([R, T] from the restart
    drivers); ``mask[i] = 1`` marks iterations that actually executed.
    ``h[i]`` is the Eq. 7 change rate of iteration i — the *paired*
    same-subsample rate in minibatch mode — and ``params`` holds the
    parameter state ``objectives[i]`` was measured at, i.e. the state whose
    partition accuracy r_i pairs with h_i (pre-update parameters in full
    mode, where J is evaluated before the update; post-update parameters in
    paired minibatch mode, where the paired J is evaluated after it).
    Index 0 of a full-mode trace carries h = inf (Eq. 7 starts at the
    second sweep); harvesting drops non-finite rows.  A minibatch trace
    with ``use_h_stop=False`` records the pre-update subsample objective
    (no paired pass runs) and h stays inf throughout — there is no Eq. 7
    signal to harvest without the pairing.
    """
    objectives: jnp.ndarray     # [T] J / loglik (per-point subsample value
                                #     in minibatch mode)
    h: jnp.ndarray              # [T] Eq. 7 change rate (paired in minibatch)
    mask: jnp.ndarray           # [T] f32 1 where the iteration executed
    params: Any                 # [T, ...] parameter trajectory


class EngineResult(NamedTuple):
    params: Any                 # centroids [K,D] | GMMParams
    labels: jnp.ndarray         # [N] int32 (local rows under shard_map)
    objective: jnp.ndarray      # [] J / loglik at the final params
    n_iters: jnp.ndarray        # [] int32
    h: jnp.ndarray              # [] last change rate observed
    trace: Any = None           # Trace when config.trace, else None


class RestartResult(NamedTuple):
    best: EngineResult          # the argbest-objective restart
    best_index: jnp.ndarray     # [] int32
    objectives: jnp.ndarray     # [R] final objective per restart
    n_iters: jnp.ndarray        # [R] iterations per restart
    traces: Any = None          # [R, T] Trace when config.trace, else None


class ShardedProgram(NamedTuple):
    """A shard_map'd fit program, its concrete arguments, and the
    mesh-resolved config — built by ``sharded_fit_callable`` /
    ``sharded_restarts_callable`` so callers can run (``fn(*args)``),
    trace (``jax.make_jaxpr(fn)(*args)``) or compile-without-running
    (``jax.jit(fn).lower(*args)``) the exact production graph."""
    fn: Any                     # shard_map'd callable
    args: tuple                 # (xc, mask, params0, h_star) concrete arrays
    config: Any                 # EngineConfig with axis_name/stats_axis_size


# --------------------------------------------------------------------------
# Streaming sweep
# --------------------------------------------------------------------------

# one chunk layout for everything: full sweeps, minibatch draws, ++ init
_chunk_points = _km.chunk_points


def _chunk_stats_fn(alg, config: EngineConfig):
    """The per-chunk masked stats pass: jnp ``chunk_stats`` or the
    dispatched kernel op, per ``config.use_kernel`` / ``kernel_backend``."""
    if config.use_kernel:
        return functools.partial(alg.kernel_chunk_stats,
                                 backend=config.kernel_backend)
    return alg.chunk_stats


def _stats_compressed(config: EngineConfig) -> bool:
    """True when this config actually runs the int8 ring (compression on,
    sharded, more than one shard — a 1-device ring is the identity)."""
    return (config.stats_compression == "int8_ef"
            and config.axis_name is not None
            and config.stats_axis_size > 1)


def _stats_reducer(alg, config: EngineConfig):
    """The per-sweep stats reduction → ``(init_ef, reduce_stats)``.

    ``reduce_stats(stats, ef, params) -> (reduced_stats, new_ef)`` replaces
    the inline psum in the fit-loop bodies.  Uncompressed (or unsharded, or
    single-shard) configs psum exactly and carry an empty ``ef = ()``.

    With ``stats_compression="int8_ef"`` the stats are first rotated into
    the algorithm's *centred* compression basis (``alg.compress_basis`` —
    moments taken around the current parameters, so the transmitted values
    shrink as the fit converges and the pmax-shared int8 scale shrinks with
    them; for EM this is what makes compression viable at all, see
    ``EMAlgorithm.compress_basis``).  Array-valued leaves (ndim >= 1) then
    go through ``compress_with_feedback`` + ``ring_allreduce_int8`` (sum
    mode, int8 on the wire, Karimireddy-style residual carried to the next
    iteration) while the scalar leaves (J / loglik) stay exact fp32 psum —
    they drive the Eq. 7 stop, where int8's ~8e-3 relative resolution is
    orders of magnitude above production h* thresholds.  The reduced tree
    is rotated back via ``alg.decompress_basis`` (an exact linear
    inversion using the reduced tree itself).

    The ring's output is bit-identical on every shard and ``params`` is
    replicated, so replicated stop decisions stay in lock-step (diverging
    trip counts under shard_map would deadlock the collective).
    """
    if not _stats_compressed(config):
        if config.axis_name is None:
            return (lambda stats_like: ()), (
                lambda stats, ef, params: (stats, ef))

        def reduce_psum(stats, ef, params):
            del params  # uncompressed leg has no error-feedback state
            return jax.tree.map(
                lambda a: jax.lax.psum(a, config.axis_name), stats), ef

        return (lambda stats_like: ()), reduce_psum

    from repro.distribution.compression import (compress_with_feedback,
                                                ring_allreduce_int8,
                                                shared_scale)
    axis, size = config.axis_name, config.stats_axis_size

    def init_ef(stats_like):
        """Zero residual buffers for the compressed (ndim >= 1) leaves."""
        return tuple(jnp.zeros(jnp.shape(a), jnp.float32)
                     for a in jax.tree.leaves(stats_like)
                     if jnp.ndim(a) >= 1)

    def reduce_stats(stats, ef, params):
        stats = alg.compress_basis(params, stats)
        flat, tree = jax.tree.flatten(stats)
        out, new_ef, i = [], [], 0
        for a in flat:
            if jnp.ndim(a) == 0:
                out.append(jax.lax.psum(a, axis))
                continue
            reduced, e = compress_with_feedback(
                a, ef[i],
                lambda g: ring_allreduce_int8(g, axis, size, mean=False),
                scale_fn=lambda g: shared_scale(g, axis, size))
            out.append(reduced)
            new_ef.append(e)
            i += 1
        reduced_stats = jax.tree.unflatten(tree, out)
        return alg.decompress_basis(params, reduced_stats), tuple(new_ef)

    return init_ef, reduce_stats


def stats_wire_bytes(stats_like, axis_size: int,
                     compression: str = "none") -> int:
    """Analytic bytes-on-wire each device sends for ONE stats reduction.

    Mirrors ``_stats_reducer``'s leaf policy: under ``int8_ef`` every
    ndim >= 1 leaf moves 1 byte/element over the ring plus one f32 scalar
    pmax for its shared scale; scalar leaves (and every leaf under
    ``none``) move 4 bytes/element.  Both paths carry the same ring factor
    2·(N−1)/N, so it cancels in int8-vs-fp32 ratios but keeps the absolute
    numbers meaningful to a cost model.  ``stats_like`` may be concrete or
    abstract (``jax.eval_shape``) — only shapes are read.
    """
    from repro.distribution.compression import ring_wire_bytes
    total = 0
    for a in jax.tree.leaves(stats_like):
        shape = jnp.shape(a)
        n = 1
        for s in shape:
            n *= int(s)
        if compression == "int8_ef" and len(shape) >= 1:
            total += ring_wire_bytes(n, axis_size)       # int8 payload
            total += ring_wire_bytes(4, axis_size)       # f32 scale pmax
        else:
            total += ring_wire_bytes(4 * n, axis_size)   # fp32 psum
    return total


def _sweep_chunked(alg, config: EngineConfig, xc, mask, params,
                   with_labels: bool, reduce: bool = True):
    """One full pass over a pre-chunked [C, P, D] layout (+ [C, P] mask)
    → (labels [C, P] | None, sufficient stats), stats psum'd over
    ``axis_name`` (``reduce=False`` leaves them shard-local for a caller-
    side reducer — the compressed-stats fit loops).  This is the layout
    the sharded drivers hand each shard (its row-slice of every global
    chunk); labels stay in chunk layout so callers can
    shard/flatten/strip-padding as they need.  With ``use_kernel`` each
    chunk runs through the dispatched kernel op (the mask operand carries
    the padding), so the sharded drivers serve both paths.

    ``config.prefetch`` double-buffers the scan: the carry holds the chunk
    being processed and the body issues the load of chunk i+1, which has no
    data dependency on the current chunk's compute — same chunk order, same
    accumulation, bit-identical stats/labels."""
    chunk_stats = _chunk_stats_fn(alg, config)
    zero = alg.zero_stats(params)

    def compute(acc, xi, mi):
        lab, st = chunk_stats(xi, mi, params)
        acc = jax.tree.map(jnp.add, acc, st)
        return acc, (lab if with_labels else jnp.zeros((), jnp.int32))

    c = xc.shape[0]
    if config.prefetch and c > 1:
        def body(carry, i):
            acc, x_cur, m_cur = carry
            nxt = jnp.minimum(i + 1, c - 1)
            x_nxt = jax.lax.dynamic_index_in_dim(xc, nxt, keepdims=False)
            m_nxt = jax.lax.dynamic_index_in_dim(mask, nxt, keepdims=False)
            acc, lab = compute(acc, x_cur, m_cur)
            return (acc, x_nxt, m_nxt), lab

        (stats, _, _), labs = jax.lax.scan(
            body, (zero, xc[0], mask[0]), jnp.arange(c))
    else:
        def body(acc, inp):
            xi, mi = inp
            return compute(acc, xi, mi)

        stats, labs = jax.lax.scan(body, zero, (xc, mask))
    if reduce and config.axis_name is not None:
        stats = jax.tree.map(
            lambda a: jax.lax.psum(a, config.axis_name), stats)
    return (labs if with_labels else None), stats


def _sweep(alg, config: EngineConfig, x, params, with_labels: bool,
           reduce: bool = True):
    """One full pass over the points → (labels | None, sufficient stats).

    chunks=1 runs the monolithic fused pass; chunks>1 streams via lax.scan
    (pure-JAX path) or via the dispatched ops' chunked entry points (fused
    path, static slices; ``config.kernel_backend`` pins a registry
    backend).  Stats are psum'd over ``axis_name`` once per sweep
    (``reduce=False`` defers to a caller-side reducer).
    """
    if config.use_kernel:
        labels, stats = alg.kernel_stats(x, params, config.chunks,
                                         backend=config.kernel_backend)
        if not with_labels:
            labels = None
    elif config.chunks <= 1:
        ones = jnp.ones((x.shape[0],), jnp.float32)
        labels, stats = alg.chunk_stats(x, ones, params)
        if not with_labels:
            labels = None
    else:
        xc, mask = _chunk_points(x, config.chunks)
        labels, stats = _sweep_chunked(alg, config, xc, mask, params,
                                       with_labels, reduce=reduce)
        if with_labels:
            labels = labels.reshape(-1)[: x.shape[0]]
        return labels, stats
    if reduce and config.axis_name is not None:
        stats = jax.tree.map(
            lambda a: jax.lax.psum(a, config.axis_name), stats)
    return labels, stats


def _minibatch_draw(config: EngineConfig, mask, key):
    """Draw B-of-C chunk *indices* without replacement → idx [B] i32.

    Only indices: the stats pass dynamic-slices each drawn chunk out of the
    resident [C, P, D] layout, so the [B, P, D] gathered copy never
    materialises and the kernel ops see statically-shaped chunks.  The
    paired Eq. 7 evaluation reuses the SAME drawn indices structurally
    (one draw per iteration), rather than leaning on PRNG determinism +
    XLA CSE to dedup a second draw.
    """
    if mask.shape[0] <= config.batch_chunks:
        # chunk_points clamps C to the row count; fail with the engine's
        # message rather than choice()'s opaque replace=False trace error
        raise ValueError(
            f"minibatch mode needs batch_chunks < effective chunks, but "
            f"the data only splits into {mask.shape[0]} chunk(s) "
            f"(batch_chunks={config.batch_chunks}, chunks={config.chunks}); "
            "reduce batch_chunks or use mode='full' at this scale")
    return jax.random.choice(key, mask.shape[0],
                             shape=(config.batch_chunks,), replace=False)


def _minibatch_stats(alg, config: EngineConfig, xc, mask, idx, params,
                     reduce: bool = True):
    """Masked stats over the drawn chunks → (stats, n_batch) — the same
    accumulation as the full sweep, over N·B/C points only, via the shared
    gather-free subsample driver (``kernels.layout.subsampled_stats``).
    ``reduce=False`` leaves the stats shard-local for a caller-side
    reducer; n_batch (a scalar the update and stop divide by) is always
    psum'd exact."""
    from repro.kernels.layout import subsampled_stats
    chunk_stats = _chunk_stats_fn(alg, config)

    def call(xi, mi):
        _, st = chunk_stats(xi, mi, params)
        return st

    stats, n_batch = subsampled_stats(call, alg.zero_stats(params),
                                      xc, mask, idx,
                                      prefetch=config.prefetch)
    if config.axis_name is not None:
        if reduce:
            stats = jax.tree.map(
                lambda a: jax.lax.psum(a, config.axis_name), stats)
        n_batch = jax.lax.psum(n_batch, config.axis_name)
    return stats, n_batch


def _minibatch_sweep(alg, config: EngineConfig, xc, mask, params, key):
    """draw + stats in one call (kept for tests / external callers)."""
    idx = _minibatch_draw(config, mask, key)
    return _minibatch_stats(alg, config, xc, mask, idx, params)


def _global_n(x, config: EngineConfig):
    n = jnp.asarray(x.shape[0], jnp.float32)
    if config.axis_name is not None:
        n = jax.lax.psum(n, config.axis_name)
    return n


# --------------------------------------------------------------------------
# Single-restart driver
# --------------------------------------------------------------------------

class _State(NamedTuple):
    params: Any
    j_curr: jnp.ndarray
    h: jnp.ndarray
    hits: jnp.ndarray
    iteration: jnp.ndarray
    moved: jnp.ndarray
    key: jnp.ndarray            # minibatch chunk-sampling stream
    carry: Any                  # minibatch step-size state (v counts)
    trace: Any                  # Trace buffers when config.trace, else ()
    ef: Any = ()                # int8_ef quantisation residuals, else ()


def _zero_trace(config: EngineConfig, params0):
    """Empty [T]-shaped trace buffers (h starts at inf — 'never measured')."""
    t = config.max_iters
    return Trace(
        objectives=jnp.zeros((t,), jnp.float32),
        h=jnp.full((t,), jnp.inf, jnp.float32),
        mask=jnp.zeros((t,), jnp.float32),
        params=jax.tree.map(
            lambda a: jnp.zeros((t,) + a.shape, jnp.float32), params0))


def _live(config: EngineConfig, iteration, hits, moved):
    """Continue-predicate shared by cond() and the per-restart masks."""
    live = iteration < config.max_iters
    if config.use_h_stop:
        live = jnp.logical_and(
            live, jnp.logical_or(iteration < 2, hits < config.patience))
    if config.stop_when_frozen:
        live = jnp.logical_and(live, moved)
    return live


def _fit_loop(alg, config: EngineConfig, params0, h_star, n_total, sweep,
              mb_data):
    """Shared single-fit driver: while_loop + Eq. 7 stop + final labels pass.

    ``sweep(params, with_labels)`` is the full-pass closure — over flat
    points (``_fit``) or over a pre-chunked shard-local layout
    (``_fit_chunked``); ``mb_data`` is the (xc, mask) chunk layout the
    minibatch draws sample from (None in full mode)."""
    minibatch = config.mode == "minibatch"
    xc, mask = mb_data if minibatch else (None, None)
    init_ef, reduce_stats = _stats_reducer(alg, config)
    init = _State(
        params=params0,
        j_curr=jnp.asarray(jnp.inf, jnp.float32),
        h=jnp.asarray(jnp.inf, jnp.float32),
        hits=jnp.asarray(0, jnp.int32),
        iteration=jnp.asarray(0, jnp.int32),
        moved=jnp.asarray(True),
        key=jax.random.PRNGKey(config.seed),
        carry=alg.zero_carry(params0) if minibatch else (),
        trace=_zero_trace(config, params0) if config.trace else (),
        ef=init_ef(alg.zero_stats(params0)),
    )

    def cond(s: _State):
        return _live(config, s.iteration, s.hits, s.moved)

    def body(s: _State):
        if minibatch:
            key, sub = jax.random.split(s.key)
            idx = _minibatch_draw(config, mask, sub)
            stats, n_batch = _minibatch_stats(alg, config, xc, mask, idx,
                                              s.params, reduce=False)
            stats, ef = reduce_stats(stats, s.ef, s.params)
            j_old = alg.objective(stats) / jnp.maximum(n_batch, 1.0)
            new_params, carry = alg.minibatch_update(
                s.params, stats, s.carry, n_batch, config.decay)
            # paired h (Eq. 7 on the SAME subsample, old vs new params):
            # raw cross-batch differences floor h at the subsampling noise,
            # while the paired ratio's sample noise cancels — so full-batch
            # fitted h* thresholds transfer to minibatch stopping.  Skipped
            # when the h predicate is off (the pairing is a second distance
            # pass; don't pay it for a value nothing reads).
            if config.use_h_stop:
                stats2, _ = _minibatch_stats(alg, config, xc, mask, idx,
                                             new_params, reduce=False)
                stats2, ef = reduce_stats(stats2, ef, s.params)
                j = alg.objective(stats2) / jnp.maximum(n_batch, 1.0)
                h = jnp.abs(j - j_old) / jnp.maximum(jnp.abs(j_old), _EPS)
                h = jnp.where(jnp.isfinite(s.h),
                              config.ema * s.h + (1.0 - config.ema) * h, h)
            else:
                j, h = j_old, s.h
        else:
            _, stats = sweep(s.params, False, reduce=False)
            stats, ef = reduce_stats(stats, s.ef, s.params)
            j = alg.objective(stats)
            new_params = alg.update(s.params, stats, n_total)
            key, carry = s.key, s.carry
            h = jnp.where(
                jnp.isfinite(s.j_curr),
                jnp.abs(j - s.j_curr) / jnp.maximum(jnp.abs(s.j_curr), _EPS),
                jnp.asarray(jnp.inf, jnp.float32))
        hits = jnp.where(h <= h_star, s.hits + 1, 0)
        moved = alg.moved(new_params, s.params)
        if config.trace:
            # record where j was measured: at s.params in full mode (the
            # sweep runs before the update) and at new_params in paired
            # minibatch mode (the second pass runs after it) — either way
            # h_i pairs with the state the iteration's transition arrived
            # at, so the harvested accuracy r_i is read off the same
            # index.  With the h predicate off, minibatch skips the paired
            # pass and j is the pre-update subsample objective — record
            # s.params then, keeping the measured-at invariant.
            paired = minibatch and config.use_h_stop
            i = s.iteration
            tr = Trace(
                objectives=s.trace.objectives.at[i].set(j),
                h=s.trace.h.at[i].set(h),
                mask=s.trace.mask.at[i].set(1.0),
                params=jax.tree.map(
                    lambda buf, p: buf.at[i].set(p), s.trace.params,
                    new_params if paired else s.params))
        else:
            tr = s.trace
        return _State(new_params, j, h, hits, s.iteration + 1, moved,
                      key, carry, tr, ef)

    final = jax.lax.while_loop(cond, body, init)
    # the labels pass is always a full sweep with the exact fp32 psum —
    # minibatch/compression only change how the parameters got there, not
    # the result contract
    labels, stats = sweep(final.params, True)
    return EngineResult(final.params, labels, alg.objective(stats),
                        final.iteration, final.h,
                        final.trace if config.trace else None)


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _fit(x, params0, h_star, alg, config: EngineConfig):
    x = x.astype(jnp.float32)
    n_total = _global_n(x, config)
    params0 = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0)
    mb = (_chunk_points(x, config.chunks)
          if config.mode == "minibatch" else None)

    def sweep(params, with_labels, reduce=True):
        return _sweep(alg, config, x, params, with_labels=with_labels,
                      reduce=reduce)

    return _fit_loop(alg, config, params0, h_star, n_total, sweep, mb)


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _fit_chunked(xc, mask, params0, h_star, alg, config: EngineConfig):
    """``_fit`` on a pre-chunked [C, P, D] (+ [C, P] mask) layout — the
    shard_map entry point.  Under ``axis_name`` every shard holds its
    row-slice of each *global* chunk, so the replicated seeded draw selects
    the same global subsample on every shard and the psum'd stats keep the
    update + paired Eq. 7 stop identical to the single-device trajectory.
    Labels come back in the [C, P] chunk layout (callers flatten and strip
    the mask-0 padding after gathering across shards)."""
    xc = xc.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    n_total = jnp.sum(mask)
    if config.axis_name is not None:
        n_total = jax.lax.psum(n_total, config.axis_name)
    params0 = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0)
    mb = (xc, mask) if config.mode == "minibatch" else None

    def sweep(params, with_labels, reduce=True):
        return _sweep_chunked(alg, config, xc, mask, params,
                              with_labels=with_labels, reduce=reduce)

    return _fit_loop(alg, config, params0, h_star, n_total, sweep, mb)


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _step(x, params, alg, config: EngineConfig):
    """One iteration: (new_params, labels, objective) — the traced drivers'
    building block, so host-loop and on-device paths share one sweep."""
    x = x.astype(jnp.float32)
    n_total = _global_n(x, config)
    labels, stats = _sweep(alg, config, x, params, with_labels=True)
    return alg.update(params, stats, n_total), labels, alg.objective(stats)


# --------------------------------------------------------------------------
# Multi-restart driver (vmap + per-restart stop masks)
# --------------------------------------------------------------------------

class _BatchState(NamedTuple):
    params: Any                 # [R, ...]
    j_curr: jnp.ndarray         # [R]
    h: jnp.ndarray              # [R]
    hits: jnp.ndarray           # [R] int32
    n_iters: jnp.ndarray        # [R] int32
    moved: jnp.ndarray          # [R] bool
    active: jnp.ndarray         # [R] bool — restart still iterating
    keys: jnp.ndarray           # [R, 2] per-restart minibatch streams
    carry: Any                  # [R, ...] minibatch step-size state
    trace: Any                  # [R, T] Trace buffers when config.trace
    ef: Any = ()                # [R, ...] int8_ef residuals, else ()


def _zero_trace_restarts(config: EngineConfig, params0, r: int):
    """[R, T]-shaped trace buffers for the vmapped restart fleet."""
    t = config.max_iters
    return Trace(
        objectives=jnp.zeros((r, t), jnp.float32),
        h=jnp.full((r, t), jnp.inf, jnp.float32),
        mask=jnp.zeros((r, t), jnp.float32),
        params=jax.tree.map(
            lambda a: jnp.zeros((r, t) + a.shape[1:], jnp.float32), params0))


def _mask_tree(active, new, old):
    """Per-leaf jnp.where with `active` broadcast over trailing dims."""
    def one(n, o):
        a = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)
    return jax.tree.map(one, new, old)


def _restart_loop(alg, config: EngineConfig, params0, h_star, n_total,
                  sweep_stats, sweep_labels, mb_data):
    """Shared multi-restart driver (vmapped body + per-restart stop masks).

    ``sweep_stats(params)`` / ``sweep_labels(params)`` are the vmapped
    full-pass closures (flat or chunked layout); ``mb_data`` is the
    (xc, mask) chunk layout per-restart minibatch draws sample from.
    Under shard_map the psums inside the closures batch over the restart
    axis (vmap-of-psum), so every shard agrees on each restart's stop
    iteration and on the final argbest."""
    r = jax.tree.leaves(params0)[0].shape[0]
    minibatch = config.mode == "minibatch"
    init_ef, reduce_stats = _stats_reducer(alg, config)
    # vmap over the restart axis: the ring/psum inside batches per restart
    # (vmap-of-collective), each restart carrying its own residual buffers
    reduce_v = jax.vmap(reduce_stats)
    if minibatch:
        xc, mask = mb_data
        mb_draw_v = jax.vmap(
            lambda kk: _minibatch_draw(config, mask, kk))
        mb_stats_v = jax.vmap(
            lambda idx, p: _minibatch_stats(alg, config, xc, mask, idx, p,
                                            reduce=False))
        mb_update_v = jax.vmap(
            lambda p, st, cv, nb: alg.minibatch_update(p, st, cv, nb,
                                                       config.decay))
    update_v = jax.vmap(alg.update, in_axes=(0, 0, None))
    objective_v = jax.vmap(alg.objective)
    moved_v = jax.vmap(alg.moved)

    inf = jnp.full((r,), jnp.inf, jnp.float32)
    zeros_i = jnp.zeros((r,), jnp.int32)
    true_b = jnp.ones((r,), bool)
    init = _BatchState(
        params=params0,
        j_curr=inf, h=inf, hits=zeros_i, n_iters=zeros_i,
        moved=true_b, active=_live(config, zeros_i, zeros_i, true_b),
        keys=jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            jax.random.PRNGKey(config.seed), jnp.arange(r)),
        carry=(jax.vmap(alg.zero_carry)(params0) if minibatch else ()),
        trace=(_zero_trace_restarts(config, params0, r)
               if config.trace else ()),
        ef=jax.vmap(lambda p: init_ef(alg.zero_stats(p)))(params0),
    )

    def cond(s: _BatchState):
        return jnp.any(s.active)

    def body(s: _BatchState):
        # every restart computes; stopped restarts are masked back to their
        # frozen state (the "no-op body" — XLA keeps one batched program)
        if minibatch:
            split = jax.vmap(jax.random.split)(s.keys)      # [R, 2, 2]
            keys, subs = split[:, 0], split[:, 1]
            idx = mb_draw_v(subs)                           # [R, B] indices
            stats, n_batch = mb_stats_v(idx, s.params)
            stats, ef = reduce_v(stats, s.ef, s.params)
            j_old = objective_v(stats) / jnp.maximum(n_batch, 1.0)
            new_params, carry = mb_update_v(s.params, stats, s.carry,
                                            n_batch)
            # paired h on the same per-restart subsample (see _fit)
            if config.use_h_stop:
                stats2, _ = mb_stats_v(idx, new_params)
                stats2, ef = reduce_v(stats2, ef, s.params)
                j = objective_v(stats2) / jnp.maximum(n_batch, 1.0)
                h = (jnp.abs(j - j_old)
                     / jnp.maximum(jnp.abs(j_old), _EPS)).astype(jnp.float32)
                h = jnp.where(jnp.isfinite(s.h),
                              config.ema * s.h + (1.0 - config.ema) * h, h)
            else:
                j, h = j_old, s.h
        else:
            stats, ef = reduce_v(sweep_stats(s.params), s.ef, s.params)
            j = objective_v(stats)
            new_params = update_v(s.params, stats, n_total)
            keys, carry = s.keys, s.carry
            h = jnp.where(
                jnp.isfinite(s.j_curr),
                jnp.abs(j - s.j_curr) / jnp.maximum(jnp.abs(s.j_curr), _EPS),
                jnp.inf).astype(jnp.float32)
        hits = jnp.where(h <= h_star, s.hits + 1, 0)
        moved = moved_v(new_params, s.params)
        a = s.active
        params = _mask_tree(a, new_params, s.params)
        j_curr = jnp.where(a, j, s.j_curr)
        h_out = jnp.where(a, h, s.h)
        hits_out = jnp.where(a, hits, s.hits)
        n_iters = jnp.where(a, s.n_iters + 1, s.n_iters)
        moved_out = jnp.where(a, moved, s.moved)
        active = jnp.logical_and(
            a, _live(config, n_iters, hits_out, moved_out))
        carry_out = _mask_tree(a, carry, s.carry) if minibatch else carry
        # stopped restarts keep their frozen residuals (nothing reads them
        # again, but the masked no-op body must stay a fixed point)
        ef_out = _mask_tree(a, ef, s.ef) if jax.tree.leaves(s.ef) else s.ef
        if config.trace:
            # per-restart scatter at each restart's own iteration counter;
            # stopped restarts are masked back (a write landing at a
            # clamped index is undone by _mask_tree).  Params recorded
            # where j was measured — see _fit_loop.
            rows = jnp.arange(r)
            idx = s.n_iters

            def scat(buf, val):
                return _mask_tree(a, buf.at[rows, idx].set(val), buf)

            tr = Trace(
                objectives=scat(s.trace.objectives, j),
                h=scat(s.trace.h, h),
                mask=scat(s.trace.mask, jnp.ones((r,), jnp.float32)),
                params=jax.tree.map(
                    scat, s.trace.params,
                    new_params if minibatch and config.use_h_stop
                    else s.params))
        else:
            tr = s.trace
        return _BatchState(params, j_curr, h_out, hits_out, n_iters,
                           moved_out, active, keys, carry_out, tr, ef_out)

    final = jax.lax.while_loop(cond, body, init)
    labels, stats = sweep_labels(final.params)
    objectives = objective_v(stats)
    best = (jnp.argmax(objectives) if alg.maximize
            else jnp.argmin(objectives)).astype(jnp.int32)
    best_result = EngineResult(
        params=jax.tree.map(lambda a: a[best], final.params),
        labels=labels[best],
        objective=objectives[best],
        n_iters=final.n_iters[best],
        h=final.h[best],
    )
    return RestartResult(best=best_result, best_index=best,
                         objectives=objectives, n_iters=final.n_iters,
                         traces=final.trace if config.trace else None)


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _fit_restarts(x, params0, h_star, alg, config: EngineConfig):
    x = x.astype(jnp.float32)
    n_total = _global_n(x, config)
    params0 = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0)
    sweep_stats = jax.vmap(
        lambda p: _sweep(alg, config, x, p, with_labels=False,
                         reduce=False)[1])
    sweep_labels = jax.vmap(
        lambda p: _sweep(alg, config, x, p, with_labels=True))
    mb = (_chunk_points(x, config.chunks)
          if config.mode == "minibatch" else None)
    return _restart_loop(alg, config, params0, h_star, n_total, sweep_stats,
                         sweep_labels, mb)


@functools.partial(jax.jit, static_argnames=("alg", "config"))
def _fit_restarts_chunked(xc, mask, params0, h_star, alg,
                          config: EngineConfig):
    """``_fit_restarts`` on the pre-chunked shard-local layout (see
    ``_fit_chunked``): vmapped restarts *inside* shard_map, per-restart
    chunk streams and stop masks, stats psum'd per restart.  The best
    restart's labels come back as [C, P] (chunk layout)."""
    xc = xc.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    n_total = jnp.sum(mask)
    if config.axis_name is not None:
        n_total = jax.lax.psum(n_total, config.axis_name)
    params0 = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0)
    sweep_stats = jax.vmap(
        lambda p: _sweep_chunked(alg, config, xc, mask, p,
                                 with_labels=False, reduce=False)[1])
    sweep_labels = jax.vmap(
        lambda p: _sweep_chunked(alg, config, xc, mask, p,
                                 with_labels=True))
    mb = (xc, mask) if config.mode == "minibatch" else None
    return _restart_loop(alg, config, params0, h_star, n_total, sweep_stats,
                         sweep_labels, mb)


# --------------------------------------------------------------------------
# Public facade
# --------------------------------------------------------------------------

class ClusteringEngine:
    """One engine, two algorithms, three drivers (step / fit / fit_restarts).

    >>> eng = ClusteringEngine("kmeans", EngineConfig(chunks=8, max_iters=100,
    ...                                               stop_when_frozen=True))
    >>> res = eng.fit(x, eng.init(key, x, k=8), h_star=1e-4)
    >>> best = eng.fit_restarts(x, key=key, k=8, restarts=4).best
    >>> mb = ClusteringEngine("kmeans", EngineConfig(
    ...     mode="minibatch", chunks=64, batch_chunks=16, patience=5,
    ...     max_iters=200))                 # touch 25% of the points per step
    >>> res = mb.fit(x, mb.init(key, x, k=8), h_star=1e-3)
    """

    def __init__(self, algorithm="kmeans", config: EngineConfig | None = None):
        self.algorithm = get_algorithm(algorithm)
        self.config = config if config is not None else EngineConfig()

    # -- initialisation ----------------------------------------------------
    def init(self, key, x, k: int):
        """Seed params; k-means++ D² sampling streams over ``config.chunks``
        so init honours the same memory envelope as the sweeps."""
        return self.algorithm.init(key, jnp.asarray(x), k,
                                   chunks=self.config.chunks)

    def init_restarts(self, key, x, k: int, restarts: int):
        """R independent seeds, stacked along a leading restart axis."""
        x = jnp.asarray(x)
        keys = jax.random.split(key, restarts)
        inits = [self.algorithm.init(kk, x, k, chunks=self.config.chunks)
                 for kk in keys]
        return jax.tree.map(lambda *leaves: jnp.stack(leaves), *inits)

    # -- drivers -----------------------------------------------------------
    def _tuning(self):
        """Autotune-cache scope for the drivers: active when
        ``config.autotune``, a no-op otherwise (and when no cache is
        installed — defaults stay bit-for-bit).  Entered around the
        driver *call*, which is where tracing resolves block shapes."""
        if not self.config.autotune:
            return contextlib.nullcontext()
        from repro.kernels import autotune as _autotune
        return _autotune.tuning(_autotune.default_cache())

    def step(self, x, params):
        """One iteration → (new_params, labels, objective)."""
        with self._tuning():
            return _step(jnp.asarray(x), params, self.algorithm, self.config)

    def fit(self, x, params0, h_star=None) -> EngineResult:
        hs = self.config.h_star if h_star is None else h_star
        with self._tuning():
            return _fit(jnp.asarray(x), params0,
                        jnp.asarray(hs, jnp.float32),
                        self.algorithm, self.config)

    def fit_restarts(self, x, params0=None, *, key=None, k=None,
                     restarts=None, h_star=None) -> RestartResult:
        """Batched multi-restart fit; pass stacked ``params0`` or
        (key, k, restarts) to draw them."""
        x = jnp.asarray(x)
        if params0 is None:
            if key is None or k is None or restarts is None:
                raise ValueError(
                    "fit_restarts needs params0 or (key, k, restarts)")
            params0 = self.init_restarts(key, x, k, restarts)
        hs = self.config.h_star if h_star is None else h_star
        with self._tuning():
            return _fit_restarts(x, params0, jnp.asarray(hs, jnp.float32),
                                 self.algorithm, self.config)

    # -- sharded drivers (shard_map over the mesh's data axes) -------------
    def _sharded_setup(self, x, mesh):
        """Chunk globally, shard each chunk's rows, derive the psum config.

        Returns (cfg, xc, mask, xc_spec, mask_spec) with xc [C, P', D] and
        mask [C, P'] placed on the mesh (P' = P padded to the data-axis
        extent; padding rows carry mask 0, so no row is ever truncated).
        """
        from jax.sharding import PartitionSpec as P
        from repro.distribution.sharding import (chunked_points_spec,
                                                 mesh_axes,
                                                 shard_chunked_points)
        dp, _, _ = mesh_axes(mesh)
        if not dp:
            raise ValueError(
                f"mesh axes {mesh.axis_names} contain no data axis (name "
                "one 'data' or 'pod'); the sharded drivers shard the "
                "points over the data axes")
        axis = dp if len(dp) > 1 else dp[0]
        if self.config.stats_compression != "none":
            if len(dp) > 1:
                raise ValueError(
                    "stats_compression rides a single-axis ppermute ring "
                    f"but mesh {mesh.axis_names} has data axes {dp}; "
                    "collapse them into one axis (or use "
                    "stats_compression='none')")
            # the ring needs its static size; a 1-device mesh degrades to
            # the exact path inside _stats_reducer
            cfg = dataclasses.replace(
                self.config, axis_name=axis,
                stats_axis_size=int(mesh.shape[dp[0]]))
        else:
            cfg = dataclasses.replace(self.config, axis_name=axis)
        xc, mask = _chunk_points(jnp.asarray(x, jnp.float32), cfg.chunks)
        xc, mask = shard_chunked_points(xc, mask, mesh)
        xc_spec = chunked_points_spec(mesh)
        return cfg, xc, mask, xc_spec, P(*tuple(xc_spec)[:2])

    @staticmethod
    def _strip_chunk_padding(labels, mask):
        """[C, P] chunk-layout labels → [N] flat labels in input row order
        (the chunk layout is row-major; padding rows have mask 0)."""
        return labels.reshape(-1)[mask.reshape(-1) > 0]

    def sharded_fit_callable(self, x, params0, mesh,
                             h_star=None) -> "ShardedProgram":
        """The shard_map'd fit program and its concrete arguments, WITHOUT
        running it.

        ``prog.fn(*prog.args)`` executes the fit;
        ``jax.make_jaxpr(prog.fn)(*prog.args)`` traces it and
        ``jax.jit(prog.fn).lower(*prog.args)`` compiles it — the static
        graph-contract rules in :mod:`repro.analysis` inspect both forms
        through this hook, so the linter checks the *same* program
        ``fit_sharded`` runs, not a reconstruction.  ``prog.config`` is
        the mesh-resolved :class:`EngineConfig` (``axis_name`` /
        ``stats_axis_size`` filled in); ``prog.args[1]`` is the padding
        mask.
        """
        from jax.sharding import PartitionSpec as P
        cfg, xc, mask, xc_spec, mask_spec = self._sharded_setup(x, mesh)
        params0 = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0)
        rep = jax.tree.map(lambda a: P(*(None,) * jnp.ndim(a)), params0)
        hs = self.config.h_star if h_star is None else h_star
        # the trace is computed from psum'd stats, so it is replicated —
        # every shard records the identical history
        tr_spec = (Trace(P(), P(), P(),
                         jax.tree.map(lambda a: P(), params0))
                   if cfg.trace else None)
        fit = jax.shard_map(
            functools.partial(_fit_chunked, alg=self.algorithm, config=cfg),
            mesh=mesh,
            in_specs=(xc_spec, mask_spec, rep, P()),
            out_specs=EngineResult(params=rep, labels=mask_spec,
                                   objective=P(), n_iters=P(), h=P(),
                                   trace=tr_spec),
            check_vma=False)
        return ShardedProgram(
            fit, (xc, mask, params0, jnp.asarray(hs, jnp.float32)), cfg)

    def fit_sharded(self, x, params0, mesh, h_star=None) -> EngineResult:
        """Distributed fit under ``shard_map`` — both engine modes.

        The points are chunked *globally* to [C, P, D] (the engine's one
        chunk layout) and each chunk's rows are sharded over the mesh's
        data axes, so a shard's local chunk c is a row-slice of global
        chunk c.  Per iteration every shard draws the same ``batch_chunks``
        chunk indices (the sampling key is replicated), computes stats over
        its resident slice, and psums once — the subsample, the
        learning-rate update, and the paired Eq. 7 stop are therefore
        identical to the single-device run up to fp32 reduction order.
        Labels cover all N input rows (chunk padding is stripped).
        """
        prog = self.sharded_fit_callable(x, params0, mesh, h_star)
        mask = prog.args[1]
        with self._tuning():
            res = prog.fn(*prog.args)
        return res._replace(labels=self._strip_chunk_padding(res.labels,
                                                             mask))

    def sharded_restarts_callable(self, x, params0=None, mesh=None, *,
                                  key=None, k=None, restarts=None,
                                  h_star=None) -> "ShardedProgram":
        """The shard_map'd multi-restart program + concrete args, without
        running it — the restarts twin of :meth:`sharded_fit_callable`."""
        from jax.sharding import PartitionSpec as P
        if mesh is None:
            raise ValueError("fit_restarts_sharded needs a mesh")
        x = jnp.asarray(x)
        if params0 is None:
            if key is None or k is None or restarts is None:
                raise ValueError(
                    "fit_restarts_sharded needs params0 or (key, k, "
                    "restarts)")
            params0 = self.init_restarts(key, x, k, restarts)
        cfg, xc, mask, xc_spec, mask_spec = self._sharded_setup(x, mesh)
        params0 = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), params0)
        rep = jax.tree.map(lambda a: P(*(None,) * jnp.ndim(a)), params0)
        best_rep = jax.tree.map(lambda a: P(*(None,) * (jnp.ndim(a) - 1)),
                                params0)
        hs = self.config.h_star if h_star is None else h_star
        tr_spec = (Trace(P(), P(), P(),
                         jax.tree.map(lambda a: P(), params0))
                   if cfg.trace else None)
        fit = jax.shard_map(
            functools.partial(_fit_restarts_chunked, alg=self.algorithm,
                              config=cfg),
            mesh=mesh,
            in_specs=(xc_spec, mask_spec, rep, P()),
            out_specs=RestartResult(
                best=EngineResult(params=best_rep, labels=mask_spec,
                                  objective=P(), n_iters=P(), h=P()),
                best_index=P(), objectives=P(None), n_iters=P(None),
                traces=tr_spec),
            check_vma=False)
        return ShardedProgram(
            fit, (xc, mask, params0, jnp.asarray(hs, jnp.float32)), cfg)

    def fit_restarts_sharded(self, x, params0=None, mesh=None, *, key=None,
                             k=None, restarts=None,
                             h_star=None) -> RestartResult:
        """Vmapped multi-restart fit *inside* ``shard_map`` (vmap-of-psum):
        every restart keeps its own replicated chunk-draw stream and stop
        mask, stats are psum'd per restart, and all shards agree on each
        restart's stop iteration and on the final best-objective index.
        Accepts stacked ``params0`` or (key, k, restarts), like
        ``fit_restarts``."""
        prog = self.sharded_restarts_callable(
            x, params0, mesh, key=key, k=k, restarts=restarts, h_star=h_star)
        mask = prog.args[1]
        with self._tuning():
            rr = prog.fn(*prog.args)
        return rr._replace(best=rr.best._replace(
            labels=self._strip_chunk_padding(rr.best.labels, mask)))
