"""Paper core: long-tail early stopping for iterative clustering in the cloud.

Pipeline (paper §4):  sample → group → trace training groups to convergence →
fit h(r) regression → pick h* = f(r*) → early-stop production runs on device.
"""
from .rand_index import (rand_index, adjusted_rand_index, contingency_table,
                         rand_index_from_contingency, sharded_contingency)
from .regression import (RegressionModel, FitMetrics, fit_family, select_model,
                         pool_traces, rh_from_objectives, FAMILIES)
from .earlystop import (LongTailModel, EarlyStopHook, fit_longtail,
                        change_rate, harvest_lm_trace)
from .longtail_train import (TrainingPlan, config_fingerprint, harvest_config,
                             harvest_traces, fit_for_config)
from .kmeans import (kmeans_step, kmeans_fit_traced, kmeans_fit_earlystop,
                     kmeans_fit_full, kmeans_plus_plus_init, random_init,
                     assign_and_stats, trace_accuracy, trace_to_rh,
                     chunk_points, minibatch_update_centroids)
from .em_gmm import (GMMParams, em_step, em_fit_traced, em_fit_earlystop,
                     em_fit_full, init_from_kmeans, estep_stats, log_prob,
                     minibatch_mstep)
from .engine import (ClusteringEngine, EngineConfig, EngineResult,
                     RestartResult, KMeansAlgorithm, EMAlgorithm,
                     get_algorithm, ProvenanceMismatchError,
                     stats_wire_bytes)
from .artifacts import ClusterArtifact, fingerprint_key, load_registry_dir
from .sampling import GroupedData, random_groups, kfold_split, make_grouped
from .cost_model import (CostReport, report, landuse_case_study,
                         EC2_ON_DEMAND_USD_PER_HOUR, TPU_ON_DEMAND_USD_PER_HOUR,
                         Price, PriceTable, expected_spot_wall_s,
                         priced_wall_s, candidate_cost_usd)
from .planner import (IterationModel, ThroughputModel, ThroughputPoint,
                      PlanSpec, CandidatePlan, PlanReport, PlanError, plan)
