"""Fitted clustering artifacts: the unit the assignment server registers.

The paper's economics rest on the asymmetry between rare, expensive
*fitting* and cheap, repeated *application* of what the fit produced
(§5.4: "the training process runs once; the regression is applied
repeatedly").  A :class:`ClusterArtifact` is the applied side's currency:
the converged cluster parameters (centroids for k-means, ``GMMParams``
for EM) together with the :class:`~repro.core.earlystop.LongTailModel`
whose stamped ``engine_config`` provenance says exactly which engine
regime both were produced under.

``fingerprint_key`` flattens that provenance (the
``longtail_train.config_fingerprint`` dict) into the registry key the
serving layer indexes models by — two artifacts harvested under the same
regime share a fingerprint and differ only by ``name``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import numpy as np

from .earlystop import LongTailModel
from .em_gmm import GMMParams


def fingerprint_key(prov: dict) -> str:
    """Deterministic flat string for a provenance fingerprint dict."""
    return "|".join(f"{k}={prov[k]}" for k in sorted(prov))


@dataclasses.dataclass(frozen=True)
class ClusterArtifact:
    """One fitted model as served: parameters + stop-model + provenance.

    ``params`` is a host-side copy (``np.ndarray`` centroids [K, D] for
    k-means; ``GMMParams`` of arrays for EM) — the registry places it on
    device at registration.  ``desired_accuracy`` is the r* the artifact
    was certified for; incremental fit jobs stop at
    ``model.threshold_for(desired_accuracy)``.
    """
    name: str
    algorithm: str                   # "kmeans" | "em"
    params: Any
    model: LongTailModel
    desired_accuracy: float = 0.95

    def __post_init__(self):
        if self.algorithm not in ("kmeans", "em"):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.algorithm == "em" and not isinstance(self.params, GMMParams):
            raise ValueError("em artifacts carry GMMParams")

    @property
    def k(self) -> int:
        if self.algorithm == "kmeans":
            return int(np.shape(self.params)[0])
        return int(np.shape(self.params.means)[0])

    @property
    def d(self) -> int:
        if self.algorithm == "kmeans":
            return int(np.shape(self.params)[1])
        return int(np.shape(self.params.means)[1])

    # ---- persistence (JSON next to the LongTailModel checkpoints) --------
    def to_json(self) -> str:
        if self.algorithm == "kmeans":
            params = {"centroids": np.asarray(self.params,
                                              np.float32).tolist()}
        else:
            params = {"means": np.asarray(self.params.means,
                                          np.float32).tolist(),
                      "var": np.asarray(self.params.var,
                                        np.float32).tolist(),
                      "log_w": np.asarray(self.params.log_w,
                                          np.float32).tolist()}
        return json.dumps({
            "name": self.name,
            "algorithm": self.algorithm,
            "desired_accuracy": self.desired_accuracy,
            "params": params,
            "model": json.loads(self.model.to_json()),
        }, indent=2)

    @staticmethod
    def from_json(s: str) -> "ClusterArtifact":
        d = json.loads(s)
        p = d["params"]
        if d["algorithm"] == "kmeans":
            params: Any = np.asarray(p["centroids"], np.float32)
        else:
            params = GMMParams(means=np.asarray(p["means"], np.float32),
                               var=np.asarray(p["var"], np.float32),
                               log_w=np.asarray(p["log_w"], np.float32))
        return ClusterArtifact(
            name=d["name"], algorithm=d["algorithm"], params=params,
            model=LongTailModel.from_json(json.dumps(d["model"])),
            desired_accuracy=float(d.get("desired_accuracy", 0.95)))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @staticmethod
    def load(path: str) -> "ClusterArtifact":
        with open(path) as f:
            return ClusterArtifact.from_json(f.read())


def load_registry_dir(path: str) -> list[ClusterArtifact]:
    """Load every ``*.json`` artifact under ``path`` (sorted by filename) —
    the on-disk registry layout the serve CLI consumes."""
    out = []
    for fn in sorted(os.listdir(path)):
        if fn.endswith(".json"):
            out.append(ClusterArtifact.load(os.path.join(path, fn)))
    return out
