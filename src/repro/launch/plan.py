"""Cost-aware provisioning planner CLI (docs/cost_planning.md walks this).

    PYTHONPATH=src python -m repro.launch.plan \
        --dataset skin --k 2 --target-r 0.99 --deadline-s 3600

Pipeline: load the dataset → sample training groups → per candidate mode,
harvest (r, h) traces under that mode's engine regime and fit BOTH the
h(r) regression (``core.longtail_train``, provenance-stamped) and the
geometric :class:`IterationModel` from the same traces → interpolate
per-iteration throughput from the committed ``BENCH_*.json`` → enumerate
(mode × devices × compression × prefetch × instance × pricing), price
each candidate (Eq. 6 at market rate, spot walls inflated by the
expected-restart model), and print the cheapest feasible plan plus the
runner-up table.

``--validate`` then executes the chosen plan through the real fit
drivers on a held-out group: the early-stopped run, the full-convergence
reference it is priced against, and a short host-stepped loop wrapped in
``training.straggler.StragglerMonitor`` so slow-shard evidence rides
along.  The predicted-vs-actual record (``benchmarks/run.py --only plan``
commits it as ``BENCH_plan.json``) is CI-gated.

Exit codes: 0 plan emitted (validation, if requested, within tolerance);
2 no feasible plan (``PlanError`` — the message names the binding
constraint); 3 validation ran but actual iterations fell outside the
stated tolerance band of predicted.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat  # noqa: F401  (shard_map / make_mesh shims)
from repro import core
from repro.core.cost_model import PriceTable, candidate_cost_usd
from repro.core.engine import ClusteringEngine, EngineConfig
from repro.core.longtail_train import (TrainingPlan, fit_for_config,
                                       harvest_traces)
from repro.core.planner import (IterationModel, PlanError, PlanReport,
                                PlanSpec, ThroughputModel, plan)
from repro.data import load as load_data
from repro.training.straggler import StragglerMonitor

EXIT_OK = 0
EXIT_INFEASIBLE = 2
EXIT_VALIDATION = 3

# predicted-vs-actual acceptance band, stamped into every validation
# record: iterations are host-independent (hard-gated in CI); wall is
# advisory (BENCH throughput was measured on a different host class)
TOLERANCE = {"iters_rel": 0.5, "iters_abs": 5, "wall_advisory": True}


def _mode_config(mode: str, *, algorithm: str, chunks: int,
                 batch_chunks: int, decay: float,
                 max_iters: int) -> EngineConfig:
    kw = dict(max_iters=max_iters, chunks=chunks, mode=mode,
              stop_when_frozen=(algorithm == "kmeans"))
    if mode == "minibatch":
        kw.update(batch_chunks=batch_chunks, decay=decay)
    return EngineConfig(**kw)


def fit_models(groups, *, algorithm: str = "kmeans", k: int = 2,
               modes=("full", "minibatch"), chunks: int = 16,
               batch_chunks: int = 4, decay: float = 0.95,
               max_iters: int = 400, family: str | None = "quadratic",
               seed: int = 0, dataset: str = "skin"):
    """Per-mode (LongTailModel, IterationModel) from ONE harvest each.

    The same iteration-ordered h sequences feed both fits: the h(r)
    regression pools (r, h) pairs, the iteration model the h trajectory —
    so the planner's two predictors cannot disagree about the regime they
    describe.
    """
    models: dict = {}
    iteration_models: dict = {}
    for mode in modes:
        cfg = _mode_config(mode, algorithm=algorithm, chunks=chunks,
                           batch_chunks=batch_chunks, decay=decay,
                           max_iters=max_iters)
        tplan = TrainingPlan(algorithm=algorithm, k=k, config=cfg,
                             family=family, max_iters=max_iters,
                             seed=seed, dataset=dataset)
        traces = harvest_traces(tplan, groups)
        models[mode] = fit_for_config(tplan, groups, traces=traces)
        iteration_models[mode] = IterationModel.from_traces(
            [h for _, h in traces])
    return models, iteration_models


def predict_for_candidate(chosen, n: int, throughput: ThroughputModel,
                          price, *, train_time_s: float = 0.0,
                          restart_overhead_s: float = 60.0,
                          checkpoint_interval_s: float | None = None):
    """Re-predict the CHOSEN candidate's wall/cost at a different N (the
    validation group is smaller than the planning target — predicted and
    actual must compare like for like)."""
    touched = (2.0 * n * chosen.batch_chunks / chosen.chunks
               if chosen.mode == "minibatch" else float(n))
    s_iter = throughput.seconds_per_iter(
        touched, chosen.devices, mode=chosen.mode, backend=chosen.backend,
        compression=chosen.stats_compression)
    wall = chosen.predicted_iters * s_iter
    cost = candidate_cost_usd(
        wall + train_time_s, price, chosen.devices, chosen.pricing,
        restart_overhead_s=restart_overhead_s,
        checkpoint_interval_s=checkpoint_interval_s)
    return {"iters": chosen.predicted_iters, "wall_s": wall,
            "cost_usd": cost}


def _monitored_steps(x, cfg: EngineConfig, algorithm: str, k: int,
                     n_steps: int, seed: int) -> dict:
    """Short host-stepped loop under the chosen config, each iteration
    timed by StragglerMonitor — the slow-shard evidence channel the
    jitted while_loop fit cannot expose (no host boundary per step).
    Fleet rebalancing on these flags stays a future PR (ROADMAP)."""
    eng = ClusteringEngine(algorithm, cfg)
    params = eng.init(jax.random.PRNGKey(seed), x, k)
    mon = StragglerMonitor(window=16, grace_steps=2)
    for _ in range(n_steps):
        mon.start()
        params, _, obj = eng.step(x, params)
        jax.block_until_ready(obj)
        mon.stop()
    return mon.report()


def validate_plan(report: PlanReport, x_val, *, algorithm: str, k: int,
                  models: dict, throughput: ThroughputModel,
                  prices: PriceTable, target_r: float, max_iters: int,
                  monitor_steps: int = 12, seed: int = 123) -> dict:
    """Execute the chosen plan through the real fit drivers and record
    predicted vs actual (iterations, wall, Eq. 6 cost at the chosen
    market rate) plus the full-convergence reference and the straggler
    report.  This dict is the body of ``BENCH_plan.json``."""
    from repro.launch.cluster import run_production

    chosen = report.chosen
    n_val = int(x_val.shape[0])
    price = prices.get(chosen.instance)
    predicted = predict_for_candidate(chosen, n_val, throughput, price)

    shard = chosen.devices > 1 and len(jax.devices()) > 1
    t0 = time.time()

    def _warm(run):
        # each leg runs twice with identical static config/shapes: the
        # first call pays XLA compilation, the second reuses the jit
        # cache — Eq. 6/10 compares steady-state compute walls, and on a
        # small validation group compile time would otherwise dominate
        # both legs and drown the comparison
        run()
        return run()

    labels, _, iters_es, wall_es = _warm(lambda: run_production(
        x_val, k, algorithm, chosen.h_star, max_iters=max_iters,
        seed=seed, shard=shard, chunks=chosen.chunks, mode=chosen.mode,
        batch_chunks=chosen.batch_chunks, decay=chosen.decay,
        model=models[chosen.mode], desired_accuracy=target_r,
        stats_compression=(chosen.stats_compression if shard else "none"),
        prefetch=chosen.prefetch))
    # the Time_full baseline the saving is measured from (Eq. 10)
    labels_f, _, iters_fu, wall_fu = _warm(lambda: run_production(
        x_val, k, algorithm, 0.0, max_iters=max_iters * 3, seed=seed,
        shard=shard, chunks=chosen.chunks))
    accuracy = float(core.rand_index(labels, labels_f, k, k))

    actual_cost = candidate_cost_usd(wall_es, price, chosen.devices,
                                     chosen.pricing)
    full_cost = candidate_cost_usd(wall_fu, price, chosen.devices,
                                   chosen.pricing)
    straggler = _monitored_steps(
        x_val, EngineConfig(**{**chosen.engine_kwargs(),
                               "max_iters": max_iters}),
        algorithm, k, monitor_steps, seed)

    iters_err = abs(iters_es - predicted["iters"])
    iters_band = max(TOLERANCE["iters_rel"] * predicted["iters"],
                     TOLERANCE["iters_abs"])
    return {
        "n_val": n_val,
        "wall_clock_validate_s": time.time() - t0,
        "predicted": predicted,
        "actual": {"iters": int(iters_es), "wall_s": wall_es,
                   "cost_usd": actual_cost, "accuracy": accuracy},
        "full_actual": {"iters": int(iters_fu), "wall_s": wall_fu,
                        "cost_usd": full_cost},
        "tolerance": TOLERANCE,
        "iters_within_tolerance": bool(iters_err <= iters_band),
        "cost_fraction_actual": (actual_cost / full_cost
                                 if full_cost > 0 else float("inf")),
        "straggler": straggler,
    }


def _parse_grid(s: str, cast=int) -> tuple:
    return tuple(cast(v) for v in s.split(",") if v)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="search the engine configuration space for the "
                    "cheapest plan meeting (r*, deadline) on a price "
                    "table; see docs/cost_planning.md")
    ap.add_argument("--target-r", type=float, default=0.99,
                    help="desired accuracy r* (Rand index vs the "
                         "full-convergence partition)")
    ap.add_argument("--deadline-s", type=float, default=3600.0,
                    help="billed-wall deadline per clustering task "
                         "(spot candidates are inflated by the "
                         "expected-restart model before this check)")
    ap.add_argument("--prices", default=None, metavar="PATH",
                    help="price-table JSON (list of {name, "
                         "on_demand_per_hour, spot_per_hour, "
                         "preemption_per_hour}); omit for the built-in "
                         "EC2+TPU defaults")
    ap.add_argument("--dataset", default="skin",
                    choices=["road3d", "skin", "poker"])
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--algorithm", default="kmeans",
                    choices=["kmeans", "em"])
    ap.add_argument("--plan-n", type=int, default=None,
                    help="N the plan targets (default: --n); throughput "
                         "is interpolated/extrapolated to this size")
    ap.add_argument("--n", type=int, default=60_000,
                    help="dataset rows to load for harvest + validation")
    ap.add_argument("--group-size", type=int, default=6_000)
    ap.add_argument("--train-groups", type=int, default=3)
    ap.add_argument("--max-iters", type=int, default=400)
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--batch-chunks", type=int, default=4)
    ap.add_argument("--decay", type=float, default=0.95)
    ap.add_argument("--patience", type=int, default=3)
    ap.add_argument("--family", default="quadratic",
                    help="'auto' runs the Eq. 8 model-selection "
                         "comparison per mode")
    ap.add_argument("--modes", default="full,minibatch",
                    help="comma list of candidate modes")
    ap.add_argument("--devices", default="1,2,4,8",
                    help="comma list of candidate device counts")
    ap.add_argument("--compressions", default="none,int8_ef",
                    help="comma list of candidate stats_compression "
                         "values (int8_ef applies to sharded minibatch)")
    ap.add_argument("--backend", default=None,
                    choices=["tpu", "gpu", "interpret", "xla"],
                    help="pin a kernel backend for every candidate "
                         "(default: the jnp sweep path)")
    ap.add_argument("--bench-dir", default=None,
                    help="directory holding the committed BENCH_*.json "
                         "(default: the repo root)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true",
                    help="execute the chosen plan on a held-out group "
                         "through the real fit drivers and record "
                         "predicted-vs-actual (+ straggler report)")
    ap.add_argument("--monitor-steps", type=int, default=12,
                    help="host-stepped iterations timed by the "
                         "StragglerMonitor during --validate")
    ap.add_argument("--json", action="store_true",
                    help="print the PlanReport JSON instead of the table")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the PlanReport (+ validation record) "
                         "JSON to PATH")
    args = ap.parse_args(argv)

    prices = PriceTable.default()
    if args.prices:
        with open(args.prices) as f:
            prices = PriceTable.from_json(f.read())

    data = load_data(args.dataset, n=args.n)
    n_groups = args.train_groups + (1 if args.validate else 0)
    groups = core.random_groups(data, args.group_size,
                                max_groups=n_groups)
    train_g = groups[:args.train_groups]
    modes = tuple(args.modes.split(","))

    t0 = time.time()
    models, iteration_models = fit_models(
        train_g, algorithm=args.algorithm, k=args.k, modes=modes,
        chunks=args.chunks, batch_chunks=args.batch_chunks,
        decay=args.decay, max_iters=args.max_iters,
        family=None if args.family == "auto" else args.family,
        seed=args.seed, dataset=args.dataset)
    t_train = time.time() - t0
    for m in modes:
        im = iteration_models[m]
        print(f"[plan] {m}: h(r) {models[m].regression.family} "
              f"R²={models[m].regression.metrics.r2:.4f} | iteration "
              f"model h0={im.h0:.3e} rho={im.rho:.4f} "
              f"floor={im.h_floor:.3e} n_full={im.n_full}")

    throughput = ThroughputModel.from_bench_dir(args.bench_dir)
    spec = PlanSpec(
        n=args.plan_n or args.n, d=int(data.shape[1]), k=args.k,
        target_r=args.target_r, deadline_s=args.deadline_s,
        prices=prices, max_iters=args.max_iters, chunks=args.chunks,
        batch_chunks=args.batch_chunks, decay=args.decay,
        patience=args.patience,
        device_grid=_parse_grid(args.devices), modes=modes,
        compressions=tuple(args.compressions.split(",")),
        backend=args.backend, train_time_s=t_train)
    try:
        report = plan(spec, models=models,
                      iteration_models=iteration_models,
                      throughput=throughput)
    except PlanError as e:
        print(f"[plan] ERROR: {e}", file=sys.stderr)
        return EXIT_INFEASIBLE

    chosen = report.chosen
    if args.json:
        print(report.to_json())
    else:
        print(report.table())
        print(f"[plan] chosen: {chosen.describe()} — "
              f"{chosen.predicted_iters} iters, "
              f"{chosen.predicted_wall_s:.3f}s wall, "
              f"${chosen.predicted_cost_usd:.8f} "
              f"({report.cost_fraction:.3f}× the full-convergence cost)")
        print(f"[plan] EngineConfig kwargs: {chosen.engine_kwargs()}")

    payload = json.loads(report.to_json())
    rc = EXIT_OK
    if args.validate:
        x_val = jnp.asarray(groups[-1], jnp.float32)
        record = validate_plan(
            report, x_val, algorithm=args.algorithm, k=args.k,
            models=models, throughput=throughput, prices=prices,
            target_r=args.target_r, max_iters=args.max_iters,
            monitor_steps=args.monitor_steps, seed=args.seed + 123)
        payload["validation"] = record
        print(f"[plan] validate: predicted {record['predicted']['iters']}"
              f" iters / ${record['predicted']['cost_usd']:.8f} vs actual"
              f" {record['actual']['iters']} iters / "
              f"${record['actual']['cost_usd']:.8f} "
              f"(accuracy {record['actual']['accuracy']:.4f}, "
              f"cost fraction {record['cost_fraction_actual']:.3f})")
        print(f"[plan] straggler: {record['straggler']}")
        if not record["iters_within_tolerance"]:
            print("[plan] VALIDATION OUT OF TOLERANCE: actual iterations "
                  f"{record['actual']['iters']} vs predicted "
                  f"{record['predicted']['iters']} (band: ±max("
                  f"{TOLERANCE['iters_rel']:.0%}, "
                  f"{TOLERANCE['iters_abs']}))", file=sys.stderr)
            rc = EXIT_VALIDATION

    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        print(f"[plan] wrote {args.out}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
