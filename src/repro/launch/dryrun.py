import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import — jax locks the device count on first init.
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell on 512 placeholder devices and
extract the roofline terms (deliverable g).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell this: builds the production mesh, derives param/opt/cache/input
shardings (repro.distribution.sharding), lowers the right step
(train_step / prefill_step / serve_step), compiles, prints
memory_analysis + cost_analysis, parses collective bytes from the
optimized HLO, and writes experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs, SHAPES, applicable
from repro.distribution import sharding as shd
from repro.distribution.hints import use_rules
from repro.models import transformer, model_zoo
from repro.training import train_loop, optimizer as opt_lib
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import make_production_mesh


@dataclasses.dataclass
class DryrunOptions:
    microbatches: int | None = None   # None = auto (≈128k tokens per micro)
    fsdp: bool = True
    remat: str | None = None          # None = arch default
    donate: bool = True
    # §Perf hillclimb knobs
    xlstm_chunk: int | None = None    # chunkwise-parallel mLSTM
    moe_groups: int | None = None     # grouped MoE dispatch (align with DP)
    window_cache: bool | None = None  # ring-buffer local KV caches


def _auto_microbatches(shape) -> int:
    tokens = shape.global_batch * shape.seq_len
    m = max(1, tokens // 131_072)
    while shape.global_batch % m:
        m -= 1
    return m


def _replicated(mesh, struct):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), struct)


def _state_shardings(state_struct, mesh, fsdp: bool):
    p_sh = shd.param_shardings(state_struct.params, mesh, fsdp=fsdp)
    return train_loop.TrainState(
        params=p_sh,
        opt=opt_lib.OptState(step=NamedSharding(mesh, P()),
                             m=shd.param_shardings(state_struct.opt.m, mesh,
                                                   fsdp=fsdp),
                             v=shd.param_shardings(state_struct.opt.v, mesh,
                                                   fsdp=fsdp)),
        ef=None,
        rng=NamedSharding(mesh, P()),
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opts: DryrunOptions | None = None):
    """Lower + compile one cell. Returns (compiled, report dict)."""
    opts = opts if opts is not None else DryrunOptions()
    cfg = get_config(arch)
    if opts.remat is not None:
        cfg = dataclasses.replace(cfg, remat=opts.remat)
    if opts.xlstm_chunk is not None:
        cfg = dataclasses.replace(cfg, xlstm_chunk=opts.xlstm_chunk)
    if opts.moe_groups is not None:
        cfg = dataclasses.replace(cfg, moe_dispatch_groups=opts.moe_groups)
    if opts.window_cache is not None:
        cfg = dataclasses.replace(cfg, windowed_local_cache=opts.window_cache)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    specs = model_zoo.input_specs(cfg, shape)
    batch_shardable = shape.global_batch % _dp_size(mesh) == 0
    rules = shd.activation_rules(mesh, batch_shardable=batch_shardable)

    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len

    t0 = time.time()
    with mesh, use_rules(rules):
        if shape.kind == "train":
            m = opts.microbatches or _auto_microbatches(shape)
            tc = train_loop.TrainConfig(microbatches=m)
            state_struct = jax.eval_shape(
                functools.partial(train_loop.init_state, cfg=cfg,
                                  train_cfg=tc), key_struct)
            state_sh = _state_shardings(state_struct, mesh, opts.fsdp)
            batch_sh = shd.input_shardings(specs, mesh, shape.global_batch)
            step = train_loop.make_train_step(cfg, tc)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,) if opts.donate else ())
            lowered = jitted.lower(state_struct, specs)
            model_flops = 6.0 * n_active * tokens
            extra = {"microbatches": m}
        elif shape.kind == "prefill":
            params_struct = jax.eval_shape(
                functools.partial(transformer.init_lm, cfg=cfg), key_struct)
            p_sh = shd.param_shardings(params_struct, mesh, fsdp=opts.fsdp)
            batch_sh = shd.input_shardings(specs, mesh, shape.global_batch)

            def prefill_step(params, batch):
                return transformer.prefill(
                    params, cfg, tokens=batch.get("tokens"),
                    embeddings=batch.get("embeddings"),
                    image_embeds=batch.get("image_embeds"))

            # pin cache output shardings to the decode-cache layout
            cache_sh = None
            if not cfg.encoder_only:
                cache_struct = model_zoo.cache_struct(cfg, shape.global_batch,
                                                      shape.seq_len)
                cache_sh = shd.input_shardings({"caches": cache_struct}, mesh,
                                               shape.global_batch)["caches"]
            jitted = jax.jit(prefill_step, in_shardings=(p_sh, batch_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params_struct, specs)
            model_flops = 2.0 * n_active * tokens
            extra = {}
        else:  # decode
            params_struct = jax.eval_shape(
                functools.partial(transformer.init_lm, cfg=cfg), key_struct)
            p_sh = shd.param_shardings(params_struct, mesh, fsdp=opts.fsdp)
            in_sh = shd.input_shardings(specs, mesh, shape.global_batch)

            def serve_step(params, batch):
                return transformer.decode_step(
                    params, cfg, batch["token"], batch["caches"], batch["pos"],
                    image_embeds=batch.get("image_embeds"))

            jitted = jax.jit(
                serve_step, in_shardings=(p_sh, in_sh),
                out_shardings=(None, in_sh["caches"]),
                donate_argnums=(1,) if opts.donate else ())
            lowered = jitted.lower(params_struct, specs)
            model_flops = 2.0 * n_active * shape.global_batch
            extra = {}
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = hlo_analysis.memory_stats(compiled)
    xla_cost = compiled.cost_analysis()
    analyzed = hlo_cost.analyze(compiled.as_text())
    rl = hlo_analysis.roofline(
        {"flops": analyzed.flops, "bytes accessed": analyzed.bytes},
        analyzed.coll, model_flops_per_chip=model_flops / n_chips)
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "params_total": get_config(arch).param_count(),
        "params_active": n_active,
        "compile_s": round(compile_s, 1),
        "memory": mem,
        "roofline": rl.as_dict(),
        "dynamic_loops": analyzed.dynamic_loops,
        "xla_cost_analysis_raw": {          # loop-bodies-once; reference only
            "flops": float(xla_cost.get("flops", 0) or 0),
            "bytes_accessed": float(xla_cost.get("bytes accessed", 0) or 0)},
        "options": dataclasses.asdict(opts),
        **extra,
    }
    return compiled, report


def _dp_size(mesh):
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# --------------------------------------------------------------------------
# Paper-workload cells: one distributed k-means / EM iteration at SpaceNet
# production scale (points sharded across the whole mesh, statistics
# all-reduced — the step the early-stopped while_loop runs repeatedly).
# --------------------------------------------------------------------------

CLUSTER_CELLS = {
    # n = 2^31 pixels ≈ 12 SpaceNet-scale image shards resident per step
    "paper-kmeans": dict(algorithm="kmeans", n=2**31, d=3, k=6),
    "paper-em": dict(algorithm="em", n=2**31, d=3, k=6),
}


def lower_cluster_cell(name: str, multi_pod: bool):
    from repro.core import kmeans as km, em_gmm
    spec = CLUSTER_CELLS[name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    all_axes = tuple(mesh.axis_names)
    n, d, kk = spec["n"], spec["d"], spec["k"]
    x_struct = jax.ShapeDtypeStruct((n, d), jnp.float32)
    x_sh = NamedSharding(mesh, P(all_axes, None))
    rep = NamedSharding(mesh, P())

    t0 = time.time()
    with mesh:
        if spec["algorithm"] == "kmeans":
            c_struct = jax.ShapeDtypeStruct((kk, d), jnp.float32)
            step = lambda x, c: km.kmeans_step(x, c)
            jitted = jax.jit(step, in_shardings=(x_sh, rep),
                             out_shardings=(rep, None, rep))
            lowered = jitted.lower(x_struct, c_struct)
            model_flops = 2.0 * n * kk * d          # the distance matmul
        else:
            params_struct = em_gmm.GMMParams(
                means=jax.ShapeDtypeStruct((kk, d), jnp.float32),
                var=jax.ShapeDtypeStruct((kk, d), jnp.float32),
                log_w=jax.ShapeDtypeStruct((kk,), jnp.float32))
            p_sh = em_gmm.GMMParams(means=rep, var=rep, log_w=rep)
            step = lambda x, p: em_gmm.em_step(x, p, n_total=float(n))
            jitted = jax.jit(step, in_shardings=(x_sh, p_sh),
                             out_shardings=(p_sh, None, rep))
            lowered = jitted.lower(x_struct, params_struct)
            model_flops = 8.0 * n * kk * d          # 3 matmuls + weighted stats
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = hlo_analysis.memory_stats(compiled)
    analyzed = hlo_cost.analyze(compiled.as_text())
    rl = hlo_analysis.roofline(
        {"flops": analyzed.flops, "bytes accessed": analyzed.bytes},
        analyzed.coll, model_flops_per_chip=model_flops / n_chips)
    return compiled, {
        "arch": name, "shape": f"step_n{n}_d{d}_k{kk}",
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "compile_s": round(compile_s, 1),
        "memory": mem, "roofline": rl.as_dict(),
        "dynamic_loops": analyzed.dynamic_loops,
    }


def run_cell(arch, shape_name, multi_pod, opts, out_dir):
    mesh_tag = "pod2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}"
    try:
        if arch in CLUSTER_CELLS:
            compiled, report = lower_cluster_cell(arch, multi_pod)
            name = f"{arch}__{report['shape']}__{mesh_tag}"
        else:
            compiled, report = lower_cell(arch, shape_name, multi_pod, opts)
        print(f"[OK] {name}: compile {report['compile_s']}s  "
              f"dominant={report['roofline']['dominant']}  "
              f"args/dev={report['memory']['argument_size_in_bytes']/2**30:.2f}GiB  "
              f"temp/dev={report['memory']['temp_size_in_bytes']/2**30:.2f}GiB")
        print("  memory_analysis:", compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print("  cost_analysis: flops/dev=%.3e bytes/dev=%.3e"
              % (ca.get("flops", 0), ca.get("bytes accessed", 0)))
    except Exception as e:
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
        print(f"[FAIL] {name}: {type(e).__name__}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(report, f, indent=1, default=str)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--xlstm-chunk", type=int, default=None)
    ap.add_argument("--moe-groups", type=int, default=None)
    ap.add_argument("--full-local-cache", action="store_true",
                    help="disable the windowed ring cache (A/B baseline)")
    args = ap.parse_args()
    opts = DryrunOptions(microbatches=args.microbatches,
                         fsdp=not args.no_fsdp, remat=args.remat,
                         xlstm_chunk=args.xlstm_chunk,
                         moe_groups=args.moe_groups,
                         window_cache=False if args.full_local_cache else None)

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    if args.all:
        archs = archs + list(CLUSTER_CELLS)
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        if arch in CLUSTER_CELLS:
            for mp in meshes:
                results.append(run_cell(arch, "step", mp, opts, args.out))
            continue
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = applicable(cfg, SHAPES[shape_name])
            if not ok:
                print(f"[SKIP] {arch}__{shape_name}: {why}")
                continue
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'pod2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if "error" not in json.load(f):
                            print(f"[CACHED] {tag}")
                            continue
                results.append(run_cell(arch, shape_name, mp, opts, args.out))
    failures = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(failures)}/{len(results)} cells compiled "
          f"({len(failures)} failures)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
