"""Roofline terms from a compiled SPMD module (§Roofline methodology).

Sources:
  · ``compiled.cost_analysis()``   — per-device HLO FLOPs + bytes accessed
  · ``compiled.as_text()``         — optimized per-device HLO; collective
    bytes are summed from the *result* sizes of every all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute op
    (async ``-start`` forms counted once, ``-done`` skipped).

Convention: all quantities are PER CHIP (the SPMD module is the per-device
program), so  term_seconds = quantity / per-chip-rate.  Hardware constants
are the v5e-class numbers fixed by the assignment.
"""
from __future__ import annotations

import dataclasses
import re

from repro.analysis.hlo_ir import type_numel_bytes

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")


def _type_bytes(type_str: str) -> int:
    return type_numel_bytes(type_str)[1]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-chip bytes moved by each collective family + op counts."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op").replace("-start", "")
        b = _type_bytes(m.group("rtype"))
        out[op] = out.get(op, 0) + b
        counts[op] = counts.get(op, 0) + 1
    out["_counts"] = counts            # type: ignore[assignment]
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops: float                  # per-chip HLO FLOPs
    hbm_bytes: float              # per-chip bytes accessed
    coll_bytes: float             # per-chip collective bytes
    coll_by_type: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float            # 6·N·D (train) / 2·N·D (inference), per chip
    useful_ratio: float           # model_flops / HLO flops

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(cost: dict, coll: dict, *, model_flops_per_chip: float) -> Roofline:
    flops = float(cost.get("flops", 0.0) or 0.0)
    hbm = float(cost.get("bytes accessed", 0.0) or 0.0)
    cb = float(sum(v for k, v in coll.items() if not k.startswith("_")))
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": cb / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=cb,
        coll_by_type={k: v for k, v in coll.items() if not k.startswith("_")},
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], dominant=dom,
        model_flops=model_flops_per_chip,
        useful_ratio=(model_flops_per_chip / flops) if flops else 0.0)


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes", "peak_memory_in_bytes")
    return {f: int(getattr(ma, f, 0) or 0) for f in fields}
