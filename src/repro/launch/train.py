"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On this CPU container it trains REDUCED configs for real (synthetic next-
token data); on a TPU slice the same driver jits with the production-mesh
shardings (--mesh production).  Early stopping via the paper's long-tail
controller: pass --earlystop-accuracy plus a regression trained on a pilot
run (or let the driver fit one from the first --pilot-steps of this run —
the LM-loop generalisation, DESIGN.md §2).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import EarlyStopHook, LongTailModel
from repro.training import Trainer, TrainConfig, OptimizerConfig


def synthetic_data(cfg, batch: int, seq: int, seed: int = 0):
    """Markov-chain token stream — learnable structure, so the loss has a
    long tail to cut (uniform random tokens would have nothing to learn)."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab
    trans = rng.dirichlet(np.full(min(v, 64), 0.1), size=v)
    support = rng.integers(0, v, size=(v, min(v, 64)))

    def gen():
        while True:
            toks = np.empty((batch, seq), np.int32)
            state = rng.integers(0, v, size=batch)
            for t in range(seq):
                toks[:, t] = state
                nxt = [support[s][rng.choice(trans.shape[1], p=trans[s])]
                       for s in state]
                state = np.asarray(nxt)
            batch_d = {"tokens": jnp.asarray(toks)}
            if cfg.encoder_only:
                batch_d = {
                    "embeddings": jnp.asarray(
                        rng.normal(0, 1, (batch, seq, cfg.d_model)),
                        cfg.act_dtype),
                    "targets": jnp.asarray(toks % cfg.vocab),
                    "mask": jnp.asarray(rng.random((batch, seq)) < 0.3),
                }
            elif cfg.family == "vlm":
                batch_d["image_embeds"] = jnp.asarray(
                    rng.normal(0, 0.02, (batch, cfg.cross_attn_tokens,
                                         cfg.d_model)), cfg.act_dtype)
            yield batch_d
    return gen()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--earlystop-accuracy", type=float, default=None)
    ap.add_argument("--earlystop-model", default=None,
                    help="JSON from a pilot run (LongTailModel.to_json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    tc = TrainConfig(
        opt=OptimizerConfig(peak_lr=args.lr, warmup_steps=20,
                            total_steps=args.steps),
        compress_grads=args.compress_grads,
        microbatches=args.microbatches)

    hook = None
    if args.earlystop_accuracy is not None and args.earlystop_model:
        with open(args.earlystop_model) as f:
            model = LongTailModel.from_json(f.read())
        hook = EarlyStopHook(model, args.earlystop_accuracy)
        print(f"long-tail controller armed: h* = {hook.h_star:.3e}")

    data = synthetic_data(cfg, args.batch, args.seq, args.seed)
    trainer = Trainer(cfg, tc, data, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, earlystop=hook,
                      seed=args.seed)
    report = trainer.run(args.steps)
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"steps={report['final_step']} stopped_early={report['stopped_early']} "
          f"loss {losses[0]:.4f} → {losses[-1]:.4f}")
    print("straggler:", report["straggler"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump({**report, "loss_curve": losses}, f, indent=1)


if __name__ == "__main__":
    main()
