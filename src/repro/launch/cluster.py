"""Production clustering driver — the paper's pipeline end-to-end (§4, §5).

    PYTHONPATH=src python -m repro.launch.cluster \
        --dataset skin --k 2 --algorithm kmeans --desired-accuracy 0.99

Pipeline: synthesize/load data → random-sample into groups → 10-fold split →
harvest (r_i, h_i) traces from the training groups through the engine's
on-device trace recording (--train-mode matched harvests under the exact
production engine configuration; full harvests full-batch sweeps, the
transfer regime) → fit the regression (model selection or pinned quadratic,
harvest regime stamped as provenance) → h* = f(r*) → early-stopped
production clustering (on-device while_loop; shard_map over the data axis
when this host has multiple devices — full sweeps, minibatch, vmapped
multi-restart and the --use-kernel fused sweeps all compose with --shard;
--kernel-backend pins a registry backend) → validation: achieved accuracy
vs. the full run + cost report (Eq. 6/9/10).

Set ``--devices N`` via XLA host-platform flag *before* launch to exercise
the distributed path, e.g.:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 python -m ... --shard
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat  # noqa: F401  (shard_map / make_mesh shims)
from repro import core
from repro.core import em_gmm
from repro.data import load as load_data, spacenet_pixels


def train_regression(groups, k: int, algorithm: str, *, max_iters: int,
                     family: str | None, use_kernel: bool = False,
                     train_mode: str = "full", production_config=None,
                     seed: int = 0):
    """Fit h(r) from the training groups.  Paper §5.3.1, mode-matched.

    Both train modes route through ``repro.core.longtail_train``: the
    engine's fit drivers record the (J, paired-h, params) trace on device
    and the accuracy r_i is read off the parameter trajectory — no
    host-side step loop re-running sweeps.

    ``train_mode="full"`` harvests full-batch traces (the legacy transfer
    regime: h* rides the paired Eq. 7 stop into whatever configuration
    production uses); ``train_mode="matched"`` harvests under
    ``production_config`` itself — same mode, chunk layout, batch draws,
    decay/ema and kernel routing the threshold will serve — which is what
    tightens the achieved-accuracy spread (ROADMAP;
    ``BENCH_longtail_matched.json``).  Either way the harvest regime is
    stamped into the model's provenance, so
    ``EngineConfig.from_longtail`` warns on a mismatch at serve time.
    """
    from repro.core.engine import EngineConfig
    from repro.core.longtail_train import TrainingPlan, fit_for_config
    t0 = time.time()
    if train_mode == "matched":
        if production_config is None:
            raise ValueError("train_mode='matched' needs the production "
                             "EngineConfig to harvest under")
        cfg = production_config
    elif train_mode == "full":
        # full-batch harvest regime; keep the kernel routing (and the pinned
        # backend) so --use-kernel trains through the same sweep math
        kw = dict(max_iters=max_iters)
        src = production_config
        if src is not None and src.use_kernel:
            kw.update(use_kernel=True, kernel_backend=src.kernel_backend)
        elif use_kernel:
            kw["use_kernel"] = True
        cfg = EngineConfig(**kw)
    else:
        raise ValueError(f"unknown train_mode {train_mode!r} "
                         "(expected 'matched' or 'full')")
    plan = TrainingPlan(algorithm=algorithm, k=k, config=cfg, family=family,
                        max_iters=max_iters, seed=seed)
    model = fit_for_config(plan, groups)
    return model, time.time() - t0


def _data_mesh():
    """A 1-axis ("data",) mesh over every visible device."""
    n_dev = len(jax.devices())
    return jax.make_mesh((n_dev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _resolve_shard(shard: bool, n_devices: int) -> bool:
    """--shard on a 1-device host cannot shard anything: say so out loud
    (with the fix) instead of silently running the replicated path while
    the user believes the distributed drivers were exercised."""
    if shard and n_devices < 2:
        print("[cluster] --shard requested but only 1 device is visible; "
              "falling back to the single-device path.  Hint: set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
              "launch to exercise the distributed drivers on one host.")
        return False
    return shard


def run_production(x, k: int, algorithm: str, h_star: float, *,
                   max_iters: int, seed: int = 0, shard: bool = False,
                   use_kernel: bool = False, patience: int = 3,
                   chunks: int = 1, restarts: int = 1,
                   mode: str = "full", batch_chunks: int = 0,
                   decay: float = 1.0, kernel_backend: str | None = None,
                   model=None, desired_accuracy: float | None = None,
                   stats_compression: str = "none", prefetch: bool = False,
                   return_params: bool = False):
    """Early-stopped production run; optional shard_map over host devices.

    ``chunks`` streams each sweep over N/C pieces; ``restarts`` runs R seeds
    as one vmapped program and keeps the best objective.  Pass a fitted
    ``model`` (LongTailModel) + ``desired_accuracy`` to derive the threshold
    through ``EngineConfig.from_longtail`` instead of a raw ``h_star``.

    ``mode="minibatch"`` samples ``batch_chunks`` of the ``chunks`` pieces
    per iteration with learning-rate updates (forgetting factor ``decay``) —
    the fitted threshold still drives the stop via the engine's paired
    Eq. 7 change rate.  Both minibatch and multi-restart compose with
    ``shard``: the engine's ``fit_sharded`` / ``fit_restarts_sharded``
    drivers chunk the points globally and shard each chunk's rows, so the
    distributed run reproduces the single-device trajectory (same seeded
    chunk draws, psum'd stats and stop decision) up to fp32 reduction
    order.

    For k-means, ``h_star == 0.0`` (no model) means the full-convergence
    reference run: stop only when the centroids freeze.  An h-based stop at
    h*=0 quits on fp32 J plateaus before the Lloyd fixed point (see
    ``kmeans_fit_full``), which would corrupt the Time_full baseline.

    ``stats_compression="int8_ef"`` routes the sharded sweeps' stats
    reductions through the int8 ring all-reduce with error feedback
    (``EngineConfig.stats_compression``); ``prefetch`` double-buffers the
    chunk scan.  ``return_params=True`` appends the fitted parameters to
    the result tuple (for ``--save-artifact``).
    """
    from repro.core.engine import ClusteringEngine, EngineConfig
    key = jax.random.PRNGKey(seed)
    x = jnp.asarray(x)

    shard = _resolve_shard(shard, len(jax.devices()))
    full_reference = (algorithm == "kmeans" and model is None
                      and float(h_star) == 0.0 and mode == "full")
    if stats_compression != "none" and full_reference:
        raise ValueError(
            "the full-convergence k-means reference stops on frozen "
            "centroids, which int8-quantised stats never reach — run the "
            "reference with stats_compression='none'")
    cfg_kw = dict(max_iters=max_iters, patience=patience, chunks=chunks,
                  use_kernel=use_kernel, use_h_stop=not full_reference,
                  stop_when_frozen=(algorithm == "kmeans"
                                    and stats_compression == "none"),
                  mode=mode, batch_chunks=batch_chunks, decay=decay,
                  stats_compression=stats_compression, prefetch=prefetch)
    if use_kernel and kernel_backend not in (None, "auto"):
        cfg_kw["kernel_backend"] = kernel_backend
    if mode == "minibatch":
        # config is a static jit argument: only bake the seed in when the
        # engine actually samples from it, or every per-group seed would
        # force a fresh full-mode compile
        cfg_kw["seed"] = seed
    if model is not None:
        if desired_accuracy is None:
            raise ValueError("model routing needs desired_accuracy")
        cfg = EngineConfig.from_longtail(model, desired_accuracy, **cfg_kw)
    else:
        cfg = EngineConfig(h_star=float(h_star), **cfg_kw)

    if restarts > 1:
        eng = ClusteringEngine(algorithm, cfg)
        if algorithm == "em":
            # match the single-restart init quality: kmeans++-seeded GMMs
            # per restart (the engine default draws uniform data points)
            keys = jax.random.split(key, restarts)
            inits = [em_gmm.init_from_kmeans(
                x, core.kmeans_plus_plus_init(kk, x, k, chunks=chunks))
                for kk in keys]
            params0 = jax.tree.map(lambda *ls: jnp.stack(ls), *inits)
        else:
            params0 = eng.init_restarts(key, x, k, restarts)
        t0 = time.time()
        rr = (eng.fit_restarts_sharded(x, params0, _data_mesh()) if shard
              else eng.fit_restarts(x, params0))
        jax.block_until_ready(rr.best.labels)
        out = (rr.best.labels, float(rr.best.objective),
               int(rr.best.n_iters), time.time() - t0)
        return out + (rr.best.params,) if return_params else out

    c0 = core.kmeans_plus_plus_init(key, x, k, chunks=chunks)
    h_star = cfg.h_star

    if shard:
        # the engine's sharded chunk-layout driver — one path for both
        # modes AND both sweep implementations: cfg already encodes the
        # stop semantics (incl. the full_reference frozen-centroids guard
        # via use_h_stop=False) and the kernel routing (the dispatched ops
        # take the chunk mask as a weight operand, so the padded layout
        # streams through Pallas exactly like through jnp), and the padded
        # layout keeps every row — the label contract matches the
        # unsharded run.  The old flat shard_map drivers (which truncated
        # N to a shardable size for use_kernel) are gone.
        eng = ClusteringEngine(algorithm, cfg)
        params0 = c0 if algorithm == "kmeans" else em_gmm.init_from_kmeans(
            x, c0)
        t0 = time.time()
        res = eng.fit_sharded(x, params0, _data_mesh())
        jax.block_until_ready(res.labels)
        out = (res.labels, float(res.objective), int(res.n_iters),
               time.time() - t0)
        return out + (res.params,) if return_params else out

    eng = ClusteringEngine(algorithm, cfg)
    params0 = c0 if algorithm == "kmeans" else em_gmm.init_from_kmeans(x, c0)
    t0 = time.time()
    res = eng.fit(x, params0)
    jax.block_until_ready(res.labels)
    out = (res.labels, float(res.objective), int(res.n_iters),
           time.time() - t0)
    return out + (res.params,) if return_params else out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="skin",
                    choices=["road3d", "skin", "poker", "spacenet"])
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--algorithm", default="kmeans", choices=["kmeans", "em"])
    ap.add_argument("--desired-accuracy", type=float, default=0.99)
    ap.add_argument("--n", type=int, default=60_000)
    ap.add_argument("--group-size", type=int, default=10_000)
    ap.add_argument("--train-groups", type=int, default=4)
    ap.add_argument("--prod-groups", type=int, default=2)
    ap.add_argument("--max-iters", type=int, default=300)
    ap.add_argument("--family", default="quadratic",
                    help="'auto' runs the paper's model-selection comparison")
    ap.add_argument("--shard", action="store_true")
    ap.add_argument("--chunks", type=int, default=1,
                    help="stream each sweep over C chunks (engine mode)")
    ap.add_argument("--mode", default="full", choices=["full", "minibatch"],
                    help="minibatch: sample --batch-chunks of --chunks per "
                         "iteration with learning-rate updates")
    ap.add_argument("--batch-chunks", type=int, default=0,
                    help="minibatch size in chunks (B of C per iteration)")
    ap.add_argument("--decay", type=float, default=1.0,
                    help="minibatch count forgetting factor (1.0 = Sculley "
                         "1/t annealing)")
    ap.add_argument("--restarts", type=int, default=1,
                    help="vmapped multi-restart count; best objective wins")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route sweeps through the kernel dispatch layer "
                         "(backend registry: Pallas compiled on TPU/GPU, "
                         "interpreter elsewhere; composes with --shard, "
                         "--restarts and --mode minibatch)")
    ap.add_argument("--kernel-backend", default="auto",
                    choices=["auto", "tpu", "gpu", "interpret", "xla"],
                    help="pin a registry backend for --use-kernel (auto "
                         "resolves from jax.default_backend(); xla is the "
                         "reference contract)")
    ap.add_argument("--train-mode", default=None,
                    choices=["matched", "full"],
                    help="harvest the h(r) training traces under the "
                         "production engine configuration ('matched' — "
                         "mode, chunks, batch draws, kernel routing) or "
                         "under plain full-batch sweeps ('full', the "
                         "transfer regime).  Default: matched when --mode "
                         "minibatch, else full")
    ap.add_argument("--stats-compression", default="none",
                    choices=["none", "int8_ef"],
                    help="compress the sharded sweeps' stats reductions "
                         "(int8 ring all-reduce with error feedback; "
                         "requires --shard)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffer the streaming chunk scan so the "
                         "next chunk's load overlaps the current compute "
                         "(bit-identical results)")
    ap.add_argument("--save-model", default=None, metavar="PATH",
                    help="write the fitted LongTailModel JSON (regression "
                         "+ harvest-regime provenance) to PATH")
    ap.add_argument("--save-artifact", default=None, metavar="PATH",
                    help="write a ClusterArtifact JSON (fitted params + "
                         "LongTailModel) from the first production group — "
                         "loadable by serve_cluster --registry")
    ap.add_argument("--instance", default="m5.large")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.kernel_backend != "auto" and not args.use_kernel:
        ap.error("--kernel-backend only applies with --use-kernel")
    if args.stats_compression != "none" and not args.shard:
        ap.error("--stats-compression only applies with --shard (it "
                 "compresses the cross-device stats reduction)")

    if args.mode == "minibatch":
        # make the bare `--mode minibatch` recipe runnable: the full-sweep
        # defaults (--chunks 1 --batch-chunks 0) cannot subsample, so pick
        # the documented 25%-touch defaults and say so
        defaulted = []
        if args.chunks < 2:
            args.chunks = 8
            defaulted.append(f"--chunks {args.chunks}")
        if args.batch_chunks < 1:
            args.batch_chunks = max(1, args.chunks // 4)
            defaulted.append(f"--batch-chunks {args.batch_chunks}")
        if defaulted:
            print("[cluster] minibatch defaults: " + " ".join(defaulted))

    n_prod = max(args.prod_groups, 1)
    if args.dataset == "spacenet":
        groups = spacenet_pixels(n_images=args.train_groups + n_prod,
                                 k_true=args.k)
    else:
        data = load_data(args.dataset, n=args.n)
        groups = core.random_groups(data, args.group_size,
                                    max_groups=args.train_groups + n_prod)
    train_g, prod_g = groups[:args.train_groups], groups[args.train_groups:]

    family = None if args.family == "auto" else args.family
    train_mode = args.train_mode or (
        "matched" if args.mode == "minibatch" else "full")
    # the regime the fitted threshold will serve — harvested under in
    # matched mode, stamped into the model's provenance in both modes
    from repro.core.engine import EngineConfig
    cfg_kw = dict(max_iters=args.max_iters, chunks=args.chunks,
                  use_kernel=args.use_kernel,
                  stop_when_frozen=(args.algorithm == "kmeans"),
                  mode=args.mode)
    if args.use_kernel and args.kernel_backend != "auto":
        cfg_kw["kernel_backend"] = args.kernel_backend
    if args.mode == "minibatch":
        cfg_kw.update(batch_chunks=args.batch_chunks, decay=args.decay)
    production_cfg = EngineConfig(**cfg_kw)
    model, t_train = train_regression(train_g, args.k, args.algorithm,
                                      max_iters=args.max_iters, family=family,
                                      train_mode=train_mode,
                                      production_config=production_cfg)
    h_star = model.threshold_for(args.desired_accuracy)
    print(f"regression ({model.regression.family}, {train_mode} harvest): "
          f"coeffs={[round(c, 6) for c in model.regression.coeffs]} "
          f"R²={model.regression.metrics.r2:.4f}")
    print(f"h*({args.desired_accuracy}) = {h_star:.3e}   "
          f"(training took {t_train:.1f}s, amortised — Eq. 9)")
    if args.save_model:
        with open(args.save_model, "w") as f:
            f.write(model.to_json() + "\n")
        print(f"saved LongTailModel → {args.save_model}")

    # production: each group is one clustering task — the paper's unit of
    # work (§5.2 "image = group"; the regression transfers within-regime)
    t_actual = t_full = 0.0
    accs, iters_es, iters_fu = [], [], []
    artifact_params = None
    for gi, g in enumerate(prod_g):
        # the fitted LongTailModel drives the threshold through EngineConfig
        labels, j, it1, t1, *rest = run_production(
            g, args.k, args.algorithm, h_star, max_iters=args.max_iters,
            seed=100 + gi, shard=args.shard, use_kernel=args.use_kernel,
            chunks=args.chunks, restarts=args.restarts,
            mode=args.mode, batch_chunks=args.batch_chunks, decay=args.decay,
            kernel_backend=args.kernel_backend,
            model=model, desired_accuracy=args.desired_accuracy,
            stats_compression=args.stats_compression, prefetch=args.prefetch,
            return_params=(args.save_artifact is not None and gi == 0))
        if rest:
            artifact_params = rest[0]
        # the full-convergence baseline always runs full sweeps — it is the
        # Time_full / 100%-accuracy reference the savings are measured from
        labels_f, j_f, it2, t2 = run_production(
            g, args.k, args.algorithm, 0.0, max_iters=args.max_iters * 3,
            seed=100 + gi, shard=args.shard, use_kernel=args.use_kernel,
            kernel_backend=args.kernel_backend, chunks=args.chunks)
        t_actual += t1
        t_full += t2
        accs.append(float(core.rand_index(labels[:labels_f.shape[0]],
                                          labels_f, args.k, args.k)))
        iters_es.append(int(it1))
        iters_fu.append(int(it2))
    acc = float(np.mean(accs))
    if args.save_artifact:
        # host-side copy of the first group's early-stopped fit, paired
        # with the stop-model that certified it — the registry unit
        # serve_cluster --registry loads
        art = core.ClusterArtifact(
            name=f"{args.dataset}-{args.algorithm}-k{args.k}",
            algorithm=args.algorithm,
            params=jax.tree.map(np.asarray, artifact_params),
            model=model, desired_accuracy=args.desired_accuracy)
        art.save(args.save_artifact)
        print(f"saved ClusterArtifact ({art.k} clusters, d={art.d}) → "
              f"{args.save_artifact}")
    rep = core.report(t_actual, t_full, time_train_s=t_train,
                      instance=args.instance)
    print(f"early-stop: {iters_es} iters {t_actual:.2f}s | "
          f"full: {iters_fu} iters {t_full:.2f}s | achieved accuracy "
          f"{acc:.4f} (per group: {[round(a, 3) for a in accs]})")
    print(f"cost-effectiveness (Eq.10) = {rep.cost_effectiveness:.3f}  "
          f"savings = ${rep.savings_usd:.6f} on {args.instance}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "dataset": args.dataset, "k": args.k,
                "algorithm": args.algorithm, "mode": args.mode,
                "desired_accuracy": args.desired_accuracy,
                "achieved_accuracy": acc, "h_star": h_star,
                "iters_earlystop": sum(iters_es),
                "iters_full": sum(iters_fu),
                "time_actual_s": t_actual, "time_full_s": t_full,
                "time_train_s": t_train,
                "cost_effectiveness": rep.cost_effectiveness,
                "regression": json.loads(model.to_json()),
            }, f, indent=1)


if __name__ == "__main__":
    main()
