"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every computation ONCE — a scan-over-48-
layers body, the microbatch accumulation loop and the chunked-attention
loops are all under-counted by their trip counts, which makes the naive
numbers useless for a roofline.  This module re-derives per-chip FLOPs,
HBM bytes and collective bytes from the optimized HLO text with loop
multiplication:

  · computations are parsed into op lists (name → result type, opcode,
    operands, attributes);
  · ``while`` trip counts come from the loop-condition computation (the
    compare-against-constant emitted by lax.scan/fori_loop; dynamic
    ``while_loop`` bounds fall back to 1 and are flagged);
  · FLOPs: dots contribute 2·numel(result)·contraction_size (operand shapes
    resolved within the computation); called computations (fusions, loop
    bodies, reducers) are charged recursively × multiplier;
  · HBM bytes: for surface ops that touch memory (fusion, dot, copy,
    gather/scatter, dynamic-(update-)slice, reduce, sort, collectives,
    parameter-free broadcast excluded), operand sizes + result size — i.e.
    the traffic of the fused kernel, not its internals;
  · collective bytes: result sizes of collective ops × multiplier, split by
    family.

This is a *model* of the per-device program, exact on loop structure,
approximate on fusion internals — see EXPERIMENTS.md §Roofline for the
validation against analytic 6·N·D.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\([^=]*?\)|[a-z][a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_MEM_OPS = {"fusion", "dot", "convolution", "copy", "gather", "scatter",
            "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
            "transpose", "reshape-and-pad", "pad", "concatenate", "select-and-scatter",
            "reduce-window", "cholesky", "triangular-solve"}


def _type_numel_bytes(type_str: str) -> tuple[int, int]:
    n_total, b_total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        n_total += n
        b_total += n * _DTYPE_BYTES[dtype]
    return n_total, b_total


@dataclasses.dataclass
class Op:
    name: str
    rtype: str
    opcode: str
    rest: str        # operand list + attributes (raw tail of the line)


def _parse_op_line(line: str) -> Op | None:
    """Parse '%name = TYPE opcode(rest' — TYPE may be a tuple type with
    nested parens, layout braces and /*index=N*/ comments."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):          # tuple type: match parens
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype = rest[:i + 1]
        tail = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    par = tail.find("(")
    if par <= 0:
        return None
    opcode = tail[:par]
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return Op(name, rtype, opcode, tail[par + 1:])


def parse_computations(hlo: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    current: list[Op] | None = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "->" in line and line.rstrip().endswith("{"):
            current = []
            comps[hdr.group(1)] = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        op = _parse_op_line(line)
        if op is not None:
            current.append(op)
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    dynamic_loops: int = 0

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {t: v * k for t, v in self.coll.items()},
                    self.dynamic_loops)

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for t, v in o.coll.items():
            self.coll[t] = self.coll.get(t, 0.0) + v
        self.dynamic_loops += o.dynamic_loops


def _trip_count(cond_ops: list[Op]) -> int | None:
    """Largest integer constant in the loop condition ≈ trip count (exact for
    lax.scan / fori_loop); None when the bound is dynamic."""
    best = None
    for op in cond_ops:
        if op.opcode == "constant":
            m = _CONST_INT_RE.search("constant(" + op.rest)
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
    return best


def _dot_flops(op: Op, types: dict[str, str]) -> float:
    out_numel = _type_numel_bytes(op.rtype)[0]
    operands = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    contract = 1
    cm = _CONTRACT_RE.search(op.rest)
    if cm and operands:
        lhs_type = types.get(operands[0])
        if lhs_type:
            shapes = _SHAPE_RE.findall(lhs_type)
            if shapes:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                for i in (int(x) for x in cm.group(1).split(",") if x):
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_numel * contract


def _fusion_surface_bytes(op: Op, operands: list[str], types: dict,
                          called: list[Op]) -> float:
    """HBM traffic of a fused kernel = its surface, EXCEPT operands the
    fusion only *slices* (scan xs arrays, embedding tables): a parameter
    consumed solely by internal dynamic-slice/gather ops is charged at the
    slice-result size, not the full array."""
    b = float(_type_numel_bytes(op.rtype)[1])          # result write
    # called-computation parameter name per position
    param_names: dict[int, str] = {}
    for o in called:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)\)", o.rest)
            if m:
                param_names[int(m.group(1))] = o.name
    # per-param usage inside the fusion
    slice_bytes: dict[str, float] = {}
    only_sliced: dict[str, bool] = {n: True for n in param_names.values()}
    for o in called:
        if o.opcode == "parameter":
            continue
        head = o.rest.split("),")[0]
        used = _OPERAND_RE.findall(head)
        for u in used:
            if u not in only_sliced:
                continue
            if o.opcode in ("dynamic-slice", "gather") and used and used[0] == u:
                slice_bytes[u] = slice_bytes.get(u, 0.0) \
                    + _type_numel_bytes(o.rtype)[1]
            else:
                only_sliced[u] = False
    for pos, name in enumerate(operands):
        t = types.get(name)
        if t is None:
            continue
        pname = param_names.get(pos)
        if pname is not None and only_sliced.get(pname) and pname in slice_bytes:
            b += slice_bytes[pname]
        else:
            b += _type_numel_bytes(t)[1]
    return b


def analyze(hlo: str, entry: str | None = None) -> Cost:
    comps = parse_computations(hlo)
    if not comps:
        return Cost()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break cycles defensively
        ops = comps.get(name, [])
        types = {op.name: op.rtype for op in ops}
        total = Cost()
        for op in ops:
            oc = op.opcode
            if oc == "while":
                body = cond = None
                bm = re.search(r"body=%([\w.\-]+)", op.rest)
                cm = _COND_ATTR_RE.search(op.rest)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = _trip_count(comps.get(cond, [])) if cond else None
                if trips is None:
                    trips, dyn = 1, 1
                else:
                    dyn = 0
                if body:
                    total.add(comp_cost(body).scaled(trips))
                total.dynamic_loops += dyn
                continue
            if oc in ("fusion", "call", "custom-call", "reduce", "sort",
                      "map", "scatter", "select-and-scatter", "reduce-window",
                      "conditional"):
                cm = _CALL_ATTR_RE.search(op.rest)
                if cm and cm.group(1) in comps:
                    inner = comp_cost(cm.group(1))
                    if oc in ("call", "conditional"):
                        total.add(inner)
                    else:
                        # fusion internals: count compute + collectives, but
                        # NOT bytes — the fused kernel's HBM traffic is its
                        # surface (operands + result), added below
                        surf = Cost(flops=inner.flops, bytes=0.0,
                                    coll=dict(inner.coll),
                                    dynamic_loops=inner.dynamic_loops)
                        total.add(surf)
            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                if not oc.endswith("-done"):
                    b = _type_numel_bytes(op.rtype)[1]
                    total.coll[base] = total.coll.get(base, 0.0) + b
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, types)
            if oc == "convolution":
                # rough: 2 × out_numel × (kernel numel / out channels)
                total.flops += 2.0 * _type_numel_bytes(op.rtype)[0] * 64
            if oc in _MEM_OPS:
                head = op.rest.split("),")[0]
                operands = _OPERAND_RE.findall(head)
                if oc == "fusion":
                    cm2 = _CALL_ATTR_RE.search(op.rest)
                    called = comps.get(cm2.group(1), []) if cm2 else []
                    total.bytes += _fusion_surface_bytes(op, operands, types,
                                                         called)
                    continue
                if oc == "dynamic-update-slice":
                    # in-place (XLA aliases the buffer): traffic = the update
                    # slice read + written, not the whole buffer
                    upd = types.get(operands[1]) if len(operands) > 1 else None
                    b = 2 * _type_numel_bytes(upd)[1] if upd else 0
                elif oc in ("dynamic-slice", "gather"):
                    # traffic = the slice/rows actually read + written out,
                    # not the sliced-from operand
                    b = 2 * _type_numel_bytes(op.rtype)[1]
                elif oc == "scatter":
                    # traffic ≈ updates read + touched region read/written
                    upd = types.get(operands[-1]) if operands else None
                    b = 3 * _type_numel_bytes(upd)[1] if upd else \
                        _type_numel_bytes(op.rtype)[1]
                else:
                    b = _type_numel_bytes(op.rtype)[1]
                    for operand in operands:
                        t = types.get(operand)
                        if t:
                            b += _type_numel_bytes(t)[1]
                total.bytes += b
        memo[name] = total
        return total

    return comp_cost(entry)
