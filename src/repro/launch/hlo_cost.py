"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every computation ONCE — a scan-over-48-
layers body, the microbatch accumulation loop and the chunked-attention
loops are all under-counted by their trip counts, which makes the naive
numbers useless for a roofline.  The analyzer re-derives per-chip FLOPs,
HBM bytes and collective bytes from the optimized HLO text with loop
multiplication:

  · computations are parsed into op lists (name → result type, opcode,
    operands, attributes);
  · ``while`` trip counts come from the loop-condition computation (the
    compare-against-constant emitted by lax.scan/fori_loop; dynamic
    ``while_loop`` bounds fall back to 1 and are flagged);
  · FLOPs: dots contribute 2·numel(result)·contraction_size (operand shapes
    resolved within the computation); called computations (fusions, loop
    bodies, reducers) are charged recursively × multiplier;
  · HBM bytes: for surface ops that touch memory (fusion, dot, copy,
    gather/scatter, dynamic-(update-)slice, reduce, sort, collectives,
    parameter-free broadcast excluded), operand sizes + result size — i.e.
    the traffic of the fused kernel, not its internals;
  · collective bytes: result sizes of collective ops × multiplier, split by
    family.

This is a *model* of the per-device program, exact on loop structure,
approximate on fusion internals — see EXPERIMENTS.md §Roofline for the
validation against analytic 6·N·D.

The parser and the analyzer itself now live in
:mod:`repro.analysis.hlo_ir` (promoted in ISSUE 8 so the static-analysis
rules and this cost model share ONE parser); this module keeps the
historical import surface.
"""
from __future__ import annotations

from repro.analysis.hlo_ir import (  # noqa: F401
    COLLECTIVES,
    DTYPE_BYTES,
    Cost,
    Op,
    analyze,
    parse_computations,
    parse_op_line,
    trip_count,
    type_numel_bytes,
)

# historical (pre-promotion) names, kept for downstream callers/tests
_DTYPE_BYTES = DTYPE_BYTES
_parse_op_line = parse_op_line
_trip_count = trip_count
_type_numel_bytes = type_numel_bytes
