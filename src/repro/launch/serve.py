"""Serving launcher: batched generation with slot-based continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_lm
from repro.serving import Server, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    srv = Server(params, cfg, n_slots=args.slots, max_seq=args.max_seq,
                 seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab,
                                             size=rng.integers(3, 12))),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature, rid=i)
            for i in range(args.requests)]
    t0 = time.time()
    out = srv.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"{len(out)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s on {len(jax.devices())} device(s))")
    for rid in sorted(out):
        print(f"  req {rid}: {out[rid][:10]}{'…' if len(out[rid]) > 10 else ''}")


if __name__ == "__main__":
    main()
