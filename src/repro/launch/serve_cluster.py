"""Clustering-as-a-service launcher: registry + continuous-batching server.

    # serve artifacts saved by launch/cluster.py --save-artifact
    PYTHONPATH=src python -m repro.launch.serve_cluster \
        --registry artifacts/ --requests 64

    # self-contained demo: fit two small models, serve a mixed stream
    PYTHONPATH=src python -m repro.launch.serve_cluster --synthetic \
        --requests 32 --fit-jobs 2

The traffic generator enqueues assignment batches of mixed sizes across
every registered model (plus optional incremental fit jobs), drains the
queue through the bucket-padded hot path, and prints the per-model p50/p99
latency, throughput, QPS and compiled-program counts the capacity planner
consumes (PAPERS.md: D-SPACE4Cloud).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import (ClusterArtifact, ClusteringEngine, EngineConfig,
                        TrainingPlan, fit_for_config, load_registry_dir)
from repro.serving import AssignRequest, ClusterServer, FitRequest, ModelRegistry


def _blobs(n, d, k, seed, spread=6.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, spread, (k, d))
    x = np.concatenate([c + rng.normal(0, 1.0, (n // k, d))
                        for c in centers])
    return x[rng.permutation(len(x))].astype(np.float32)


def demo_artifacts(seed: int = 0, n: int = 3000, d: int = 4,
                   k: int = 3) -> list[ClusterArtifact]:
    """Two small fitted artifacts under distinct engine regimes — a
    minibatch k-means and a full-batch EM — for the demo/smoke path (and
    the serve benchmark, which needs models with real provenance)."""
    groups = np.stack([_blobs(n, d, k, seed + g) for g in range(2)])
    out = []
    for name, algorithm, config in (
            ("kmeans-mb", "kmeans",
             EngineConfig(mode="minibatch", chunks=8, batch_chunks=2,
                          patience=3, max_iters=60)),
            ("em-full", "em", EngineConfig(max_iters=40))):
        plan = TrainingPlan(algorithm=algorithm, k=k, config=config,
                            family="quadratic", seed=seed)
        model = fit_for_config(plan, groups)
        eng = ClusteringEngine(algorithm, config)
        x = groups[0]
        res = eng.fit(x, eng.init(jax.random.PRNGKey(seed), x, k),
                      h_star=model.threshold_for(0.95))
        params = jax.tree.map(np.asarray, res.params)
        out.append(ClusterArtifact(name=name, algorithm=algorithm,
                                   params=params, model=model,
                                   desired_accuracy=0.95))
    return out


def run_traffic(server: ClusterServer, keys, *, requests: int,
                min_batch: int, max_batch: int, fit_jobs: int, d: int,
                seed: int):
    """Enqueue a mixed stream across ``keys`` and drain it."""
    rng = np.random.default_rng(seed)
    rid = 0
    for _ in range(requests):
        key = keys[rng.integers(0, len(keys))]
        n = int(rng.integers(min_batch, max_batch + 1))
        server.submit(AssignRequest(x=rng.normal(0, 4, (n, d)), model_key=key,
                                    rid=rid))
        rid += 1
    for _ in range(fit_jobs):
        key = keys[rng.integers(0, len(keys))]
        n = int(rng.integers(max(min_batch, 64), max_batch + 1))
        server.submit(FitRequest(x=rng.normal(0, 4, (n, d)), model_key=key,
                                 rid=rid))
        rid += 1
    return server.drain()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="directory of ClusterArtifact *.json files")
    ap.add_argument("--synthetic", action="store_true",
                    help="fit two small demo artifacts instead of loading")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--fit-jobs", type=int, default=0)
    ap.add_argument("--min-batch", type=int, default=20)
    ap.add_argument("--max-batch", type=int, default=800)
    ap.add_argument("--buckets", default="256,1024,4096",
                    help="comma-separated bucket sizes (compile shapes)")
    ap.add_argument("--fit-steps", type=int, default=20,
                    help="max engine iterations per incremental fit job")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip pre-compiling the bucket programs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the metrics summary as JSON")
    args = ap.parse_args()

    if args.synthetic:
        artifacts = demo_artifacts(args.seed)
    elif args.registry:
        artifacts = load_registry_dir(args.registry)
    else:
        ap.error("pass --registry DIR or --synthetic")
    if not artifacts:
        ap.error("no artifacts to serve")

    buckets = tuple(int(b) for b in args.buckets.split(","))
    registry = ModelRegistry(devices=len(jax.devices()),
                             fit_steps=args.fit_steps)
    keys = [registry.register(a) for a in artifacts]
    server = ClusterServer(registry, buckets=buckets)
    for key in keys:
        print(f"registered {key}")
        if not args.no_warmup:
            server.warmup(key)

    d = artifacts[0].d
    results = run_traffic(server, keys, requests=args.requests,
                          min_batch=args.min_batch,
                          max_batch=min(args.max_batch, buckets[-1]),
                          fit_jobs=args.fit_jobs, d=d, seed=args.seed)

    summary = {"metrics": server.metrics.summary(),
               "compiled_programs": server.compiled_programs(),
               "n_results": len(results)}
    for key, m in sorted(summary["metrics"].items()):
        print(f"{key}: {m['requests']} req / {m['batches']} batches, "
              f"p50 {m['p50_latency_ms']:.2f}ms p99 "
              f"{m['p99_latency_ms']:.2f}ms, "
              f"{m['throughput_points_per_s']:.0f} pts/s, "
              f"{m['qps']:.1f} qps")
    for key, c in sorted(summary["compiled_programs"].items()):
        print(f"{key}: {c['assign']} assign / {c['fit']} fit "
              "compiled programs")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
