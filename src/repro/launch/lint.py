import argparse
import os
import pathlib
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="Graph-contract linter: statically verify collective, "
                    "dtype, transfer and recompile invariants across every "
                    "engine configuration (rules GC001-GC006), plus the "
                    "repo's AST-level source contracts (AST001-AST004).")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids or names to run "
                         "(default: all); e.g. GC001,GC005 or "
                         "collective-uniformity")
    ap.add_argument("--suppress", default=None,
                    help="comma-separated rule ids/names to run but not "
                         "fail on (kept in the report, suppressed=true)")
    ap.add_argument("--config-matrix", choices=("quick", "full"),
                    default="full", dest="matrix",
                    help="engine config matrix to trace: full = all 16 "
                         "(mode x kernel x compression x prefetch) cells, "
                         "quick = a 4-cell diagonal covering each option")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="also write the report (in --format) to this file")
    ap.add_argument("--devices", type=int, default=8,
                    help="XLA host device count for the lint substrate "
                         "(default 8; set before jax initialises)")
    ap.add_argument("--src", default=None,
                    help="source root for the AST rules (default: the "
                         "installed repro package directory)")
    ap.add_argument("--no-restarts", action="store_true",
                    help="trace only fit_sharded, not fit_restarts_sharded "
                         "(halves lint time)")
    return ap.parse_args(argv)


def _split(csv):
    return [t for t in (csv or "").split(",") if t.strip()]


def main(argv=None) -> int:
    args = _parse_args(argv)

    # device count must be pinned before jax initialises the backend
    flag = f"--xla_force_host_platform_device_count={args.devices}"
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = \
            (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    import repro.compat  # noqa: F401
    from repro.analysis import ast_rules, engine_contracts
    from repro.analysis.report import apply_suppressions, normalize_rule_ids

    rules = sorted(normalize_rule_ids(_split(args.rules))) if args.rules \
        else sorted(engine_contracts.GRAPH_RULES) + \
        ["AST001", "AST002", "AST003", "AST004"]

    graph_rules = [r for r in rules if r.startswith("GC")]
    report = engine_contracts.run_graph_lint(
        matrix=args.matrix, rules=graph_rules,
        include_restarts=not args.no_restarts)
    report.rules_run = list(rules)

    if any(r.startswith("AST") for r in rules):
        src = pathlib.Path(args.src) if args.src else \
            pathlib.Path(ast_rules.__file__).resolve().parents[1]
        report.extend([f for f in ast_rules.check_paths(src)
                       if f.rule in rules])

    apply_suppressions(report.findings, _split(args.suppress))

    rendered = report.to_json() if args.format == "json" \
        else report.to_text()
    print(rendered)
    if args.out:
        pathlib.Path(args.out).write_text(rendered + "\n")
    # any unsuppressed finding fails the gate — warnings included; waiving
    # is always an explicit --suppress
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
