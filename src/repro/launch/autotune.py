"""Kernel-autotuner CLI (ISSUE 9).

Sweep candidate block shapes per (op, backend, problem shape) and write
the winner cache::

    python -m repro.launch.autotune \
        --ops kmeans_assign,gmm_estep --backends interpret,xla \
        --shapes 16384x8x16,65536x8x4 --out autotune_cache.json

Shapes are ``NxKxD`` triples — rows × clusters × features for the
clustering ops, Sq × Skv × head_dim for ``flash_attention`` — applied to
every selected op.  The cache is versioned JSON
(``repro.kernels.autotune.AutotuneCache``); point
``REPRO_AUTOTUNE_CACHE`` (or ``autotune.set_default_cache``) at it and
run the engine with ``EngineConfig(autotune=True)`` to serve the tuned
blocks.  ``--merge`` loads an existing ``--out`` first and only tunes
missing cells (the cache-hit short-circuit skips re-timing).
"""
from __future__ import annotations

import argparse
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.autotune",
        description="Roofline-driven kernel autotuner: sweep block shapes "
                    "per (op, backend, shape), time with the shared "
                    "methodology, cache winners in versioned JSON.")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op names (default: every "
                         "supported registered op)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated backends (default: interpret + "
                         "xla, plus tpu/gpu when the hardware is present)")
    ap.add_argument("--shapes", default=None,
                    help="comma-separated NxKxD triples (clustering: rows x "
                         "clusters x features; flash_attention: Sq x Skv x "
                         "head_dim); default: per-op suite")
    ap.add_argument("--reps", type=int, default=5,
                    help="timed reps per candidate (median-of-k; default 5)")
    ap.add_argument("--warmup", type=int, default=1,
                    help="untimed warmup calls per candidate (default 1)")
    ap.add_argument("--out", default="autotune_cache.json",
                    help="cache path to write (default autotune_cache.json)")
    ap.add_argument("--merge", action="store_true",
                    help="load --out first and only tune missing cells")
    return ap.parse_args(argv)


def _split(csv):
    return [t.strip() for t in (csv or "").split(",") if t.strip()] or None


def _parse_shapes(csv):
    if not csv:
        return None
    shapes = []
    for tok in csv.split(","):
        parts = tok.strip().lower().split("x")
        if len(parts) != 3:
            raise SystemExit(f"--shapes entry {tok!r} is not an NxKxD "
                             "triple (e.g. 16384x8x16)")
        shapes.append(tuple(int(p) for p in parts))
    return shapes


def main(argv=None) -> int:
    args = _parse_args(argv)
    import os

    from repro.kernels import autotune

    cache = None
    if args.merge and os.path.exists(args.out):
        cache = autotune.AutotuneCache.load(args.out)
        print(f"# merged {len(cache.entries)} cached cell(s) from "
              f"{args.out}")
    cache = autotune.tune(
        ops=_split(args.ops), backends=_split(args.backends),
        shapes=_parse_shapes(args.shapes), reps=args.reps,
        warmup=args.warmup, cache=cache, log=print)
    cache.save(args.out)
    print(f"# wrote {len(cache.entries)} cell(s) to {args.out} "
          f"(schema v{autotune.SCHEMA_VERSION}, device "
          f"{autotune.device_kind()})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
