"""Production mesh builders + latency-hiding XLA flag toggles.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Topology (TPU v5e-class target):
  single-pod: (data=16, model=16)            = 256 chips
  multi-pod:  (pod=2, data=16, model=16)     = 512 chips
The design scales by growing "pod" (pure DP across pods — only gradient
all-reduce crosses the DCN) and "data".

The latency-hiding helpers below wire the async-collective /
latency-hiding-scheduler XLA flags (SNIPPETS.md snippet 1) into launches
as a profiled on/off toggle: ``BENCH_sharded_overlap.json`` records
wall-clock per sweep with and without them.  XLA reads ``XLA_FLAGS`` once
at backend initialisation, so the toggle only works process-wide — set it
in the environment of a fresh process (``overlap_env`` builds one), never
after jax has initialised.
"""
from __future__ import annotations

import os

import jax

from repro import compat  # noqa: F401  (AxisType / make_mesh shims)

# The scheduler/stream flags this jaxlib's XLA still parses.  The full
# SNIPPETS.md set also named --xla_gpu_enable_async_collectives and the
# Triton fusion toggles; async collectives are default-on (the flag was
# removed upstream) and unknown flags make XLA abort at startup, so they
# are deliberately absent here.
LATENCY_HIDING_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    # CPU backend counterpart: the concurrency-optimized thunk scheduler
    # overlaps independent thunks (our prefetched chunk copies) on host
    # platforms, which is what the CI/bench substrate runs on
    "--xla_cpu_enable_concurrency_optimized_scheduler=true",
)


def latency_hiding_xla_flags(base: str | None = None) -> str:
    """``XLA_FLAGS`` value with the latency-hiding set appended to ``base``
    (defaults to the current environment's value); already-present flags
    are not duplicated."""
    flags = (os.environ.get("XLA_FLAGS", "") if base is None else base)
    parts = flags.split()
    for f in LATENCY_HIDING_FLAGS:
        name = f.split("=", 1)[0]
        if not any(p.split("=", 1)[0] == name for p in parts):
            parts.append(f)
    return " ".join(parts)


def overlap_env(env: dict | None = None, enable: bool = True) -> dict:
    """A copy of ``env`` (default ``os.environ``) with the latency-hiding
    flags toggled — the bench/launcher handoff for spawning a fresh process
    per flag configuration (XLA parses the variable exactly once)."""
    out = dict(os.environ if env is None else env)
    if enable:
        out["XLA_FLAGS"] = latency_hiding_xla_flags(out.get("XLA_FLAGS", ""))
    return out


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_axis: int = 1):
    """Whatever this host has (tests/examples): (data=N/model, model)."""
    n = len(jax.devices())
    if model_axis < 1:
        raise ValueError(f"model_axis must be >= 1; got {model_axis}")
    if n % model_axis:
        raise ValueError(
            f"model_axis={model_axis} does not divide the {n} available "
            f"devices — a ({n // model_axis}, {model_axis}) mesh would "
            f"silently drop {n - (n // model_axis) * model_axis} of them; "
            "pick a model_axis that divides the device count")
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"),
                         axis_types=_auto(2))
