"""Production mesh builders.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Topology (TPU v5e-class target):
  single-pod: (data=16, model=16)            = 256 chips
  multi-pod:  (pod=2, data=16, model=16)     = 512 chips
The design scales by growing "pod" (pure DP across pods — only gradient
all-reduce crosses the DCN) and "data".
"""
from __future__ import annotations

import jax

from repro import compat  # noqa: F401  (AxisType / make_mesh shims)


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_axis: int = 1):
    """Whatever this host has (tests/examples): (data=N/model, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"),
                         axis_types=_auto(2))
