"""Sharding rules: logical-axis → PartitionSpec for params, activations,
inputs and caches, per mesh.

Mesh axes: ``("pod", "data", "model")`` multi-pod, ``("data", "model")``
single-pod.  Policy (DESIGN.md §4):

  · batch            → ("pod", "data")      — pure DP across pods (pods talk
                                              only for gradient all-reduce)
  · heads/ffn/vocab/experts → "model"       — tensor/expert parallel inside a pod
  · params' other large axis → "data"       — FSDP (never across pods)
  · decode KV caches → sequence over "model" (flash-decode style partial
    softmax), batch over DP axes; long_500k (batch=1) shards sequence over
    ("data","model") and recurrent-state feature axes over "model".

Param specs are derived from leaf *paths* (module naming is the contract);
leaves under "blocks" carry a leading stacked-period axis → specs get a
leading None.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .hints import ShardingRules

# path-regex → spec builder (dp = FSDP axis name or None, tp = "model")
# Applied in order; first match wins. Specs are for the UNSTACKED leaf.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                    ("tp", "dp")),       # [V, D]
    (r"head$",                     ("dp", "tp")),       # [D, V]
    (r"attn/(wq|wk|wv)$",          ("dp", "tp")),       # [D, H·dh]
    (r"attn/wo$",                  ("tp", "dp")),       # [H·dh, D]
    (r"attn/(bq|bk|bv)$",          ("tp",)),
    (r"(mlp|shared)/(w_gate|w_up)$", ("dp", "tp")),     # [D, F]
    (r"(mlp|shared)/w_down$",      ("tp", "dp")),       # [F, D]
    (r"moe/router$",               (None, None)),
    (r"moe/(w_gate|w_up)$",        ("tp", "dp", None)), # [E, D, F]
    (r"moe/w_down$",               ("tp", None, "dp")), # [E, F, D]
    (r"mamba/in_proj$",            ("dp", "tp")),
    (r"mamba/out_proj$",           ("tp", "dp")),
    (r"mamba/conv_w$",             (None, "tp")),
    (r"mamba/x_proj$",             ("tp", None)),
    (r"mamba/dt_w$",               (None, "tp")),
    (r"mamba/(dt_b|D)$",           ("tp",)),
    (r"mamba/A_log$",              ("tp", None)),
    (r"cell/up$",                  ("dp", "tp")),
    (r"cell/(wq|wk|wv)$",          (None, "tp")),
    (r"cell/down$",                ("tp", "dp")),
    (r"cell/(wi|wf)$",             ("tp", None)),
    (r"cell/w$",                   ("dp", "tp")),       # slstm in-proj
    (r"cell/r$",                   (None, None, None)), # block-diag, small
    (r"cell/(ff_gate|ff_up)$",     ("dp", "tp")),
    (r"cell/ff_down$",             ("tp", "dp")),
    (r"cell/gnorm$",               ("tp",)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def mesh_axes(mesh: Mesh) -> tuple[tuple[str, ...], str | None, str | None]:
    """(dp_batch_axes, fsdp_axis, tp_axis) present in this mesh."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    fsdp = "data" if "data" in names else None
    tp = "model" if "model" in names else None
    return dp, fsdp, tp


def param_spec(path, leaf, mesh: Mesh, *, fsdp: bool = True) -> P:
    """PartitionSpec for one param leaf (handles the stacked-period axis)."""
    _, fsdp_axis, tp_axis = mesh_axes(mesh)
    if not fsdp:
        fsdp_axis = None
    s = _path_str(path)
    stacked = s.startswith("blocks")
    shape = leaf.shape[1:] if stacked else leaf.shape
    spec: tuple = ()
    for pat, axes in _PARAM_RULES:
        if re.search(pat, s):
            spec = tuple({"dp": fsdp_axis, "tp": tp_axis, None: None}[a]
                         for a in axes)
            break
    if len(spec) != len(shape):       # norms/scales/unmatched → replicate
        spec = (None,) * len(shape)
    # divisibility guard: drop axes that don't divide evenly (GSPMD would
    # pad; we prefer the predictable layout)
    spec = tuple(
        ax if (ax is not None and shape[i] % _axis_size(mesh, ax) == 0) else None
        for i, ax in enumerate(spec))
    if stacked:
        spec = (None,) + spec
    return P(*spec)


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def param_shardings(params_struct, mesh: Mesh, *, fsdp: bool = True):
    """NamedSharding pytree matching an eval_shape'd params structure."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh,
                                                          fsdp=fsdp)),
        params_struct)


# --------------------------------------------------------------------------
# Clustering point sets (ClusteringEngine data-parallel path)
# --------------------------------------------------------------------------

def points_spec(mesh: Mesh) -> P:
    """[N, D] clustering points: N over the data axes, D replicated — the
    layout the engine's per-sweep psum of [K,D]+[K]+[1] stats assumes
    (the full-sweep shard_map drivers).

    Minibatch mode shards the pre-chunked [C, N/C, D] layout instead (see
    :func:`chunked_points_spec`): chunking *before* sharding keeps every
    shard's local chunk a row-slice of the global chunk, so the replicated
    chunk draw subsamples identically to the single-device run.
    """
    dp, _, _ = mesh_axes(mesh)
    return P(dp if dp else None, None)


def shard_points(x, mesh: Mesh):
    """Truncate N to a multiple of the data-axis extent and place the array.

    Returns (sharded [N', D] jax.Array, n_dropped).  Truncation (vs padding)
    keeps every resident row a real point, so the engine needs no global
    validity mask; callers stream the dropped tail separately if they care.
    """
    dp, _, _ = mesh_axes(mesh)
    size = _axis_size(mesh, dp) if dp else 1
    n = x.shape[0] // size * size
    xs = jax.device_put(jax.numpy.asarray(x[:n]),
                        NamedSharding(mesh, points_spec(mesh)))
    return xs, x.shape[0] - n


def chunked_points_spec(mesh: Mesh) -> P:
    """[C, N/C, D] pre-chunked points (``kmeans.chunk_points`` layout):
    chunk axis replicated, rows-within-chunk over the data axes, D
    replicated.

    This is the layout the engine's sharded minibatch/restart drivers use:
    every shard holds a row-slice of each *global* chunk, so the replicated
    seeded chunk draw selects the same global subsample on every shard, and
    shard-local stats only need the engine's once-per-iteration psum.  The
    accompanying [C, N/C] validity mask shards as ``P(*spec[:2])``.
    """
    dp, _, _ = mesh_axes(mesh)
    return P(None, dp if dp else None, None)


def shard_chunked_points(xc, mask, mesh: Mesh):
    """Pad a [C, P, D] chunk layout's row axis to the data-axis extent and
    place (xc, mask) with :func:`chunked_points_spec`.

    Padding (vs ``shard_points``'s truncation) is correct here because the
    chunk layout already carries a validity mask — padded rows get mask 0
    and contribute nothing to the masked sufficient statistics, so no input
    row is dropped on the sharded path.
    """
    dp, _, _ = mesh_axes(mesh)
    size = _axis_size(mesh, dp) if dp else 1
    pad = (-xc.shape[1]) % size
    if pad:
        xc = jax.numpy.pad(xc, ((0, 0), (0, pad), (0, 0)))
        mask = jax.numpy.pad(mask, ((0, 0), (0, pad)))
    spec = chunked_points_spec(mesh)
    xs = jax.device_put(jax.numpy.asarray(xc), NamedSharding(mesh, spec))
    ms = jax.device_put(jax.numpy.asarray(mask),
                        NamedSharding(mesh, P(*tuple(spec)[:2])))
    return xs, ms


# --------------------------------------------------------------------------
# Activation hint rules
# --------------------------------------------------------------------------

def activation_rules(mesh: Mesh, *, batch_shardable: bool = True) -> ShardingRules:
    dp, _, tp = mesh_axes(mesh)
    b = dp if (dp and batch_shardable) else None
    return ShardingRules({
        "act_btd":    P(b, None, None),
        "act_bshd":   P(b, None, tp, None),
        "act_btf":    P(b, None, tp),
        "logits_btv": P(b, None, tp),
        "moe_ecd":    P(tp, None, None),
        "moe_ecf":    P(tp, None, None),
        # grouped dispatch (hillclimb #2): groups ride the DP axes; the
        # ep-layout hints trigger the buffer all-to-all into expert parallel
        "moe_gtd":     P(b, None, None),
        "moe_gecd_dp": P(b, None, None, None),
        "moe_gecd_ep": P(None, tp, None, None),
        "moe_gecf_ep": P(None, tp, None, None),
    })


# --------------------------------------------------------------------------
# Input / cache shardings per (arch × shape)
# --------------------------------------------------------------------------

def _largest_divisible_axis(shape, sizes_needed: int, skip=()):
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if i not in skip and shape[i] % sizes_needed == 0 and shape[i] >= sizes_needed:
            return i
    return None


def cache_spec(path, leaf, mesh: Mesh, batch: int) -> P:
    """Decode-cache leaf spec.  Leaves are [P, B, ...] stacks."""
    dp, _, tp = mesh_axes(mesh)
    s = _path_str(path)
    shape = leaf.shape
    dp_size = _axis_size(mesh, dp) if dp else 1
    spec = [None] * len(shape)
    if dp and batch % dp_size == 0 and batch >= dp_size:
        spec[1] = dp
        # K/V: seq over model; states: feature axis over model
        if tp:
            if re.search(r"/(k|v)$", s):
                if shape[2] % mesh.shape[tp] == 0:
                    spec[2] = tp            # sequence (flash-decode)
            else:
                i = _largest_divisible_axis(shape, mesh.shape[tp], skip=(0, 1))
                if i is not None:
                    spec[i] = tp
    else:
        # batch=1 (long_500k): spread sequence/feature over everything
        combo = tuple(a for a in (("data",) if "data" in mesh.axis_names else ())
                      ) + ((tp,) if tp else ())
        combo = tuple(a for a in ("data", "model") if a in mesh.axis_names)
        if re.search(r"/(k|v)$", s) and combo:
            n = _axis_size(mesh, combo)
            if shape[2] % n == 0:
                spec[2] = combo
        elif tp:
            i = _largest_divisible_axis(shape, mesh.shape[tp], skip=(0, 1))
            if i is not None:
                spec[i] = tp
    return P(*spec)


def input_shardings(specs: dict, mesh: Mesh, batch: int):
    """NamedSharding pytree for an ``input_specs`` dict (any shape kind)."""
    dp, _, tp = mesh_axes(mesh)
    dp_size = _axis_size(mesh, dp) if dp else 1
    batch_ok = dp and batch % dp_size == 0 and batch >= dp_size

    def one(path, leaf):
        s = _path_str(path)
        if s.startswith("caches"):
            return NamedSharding(mesh, cache_spec(path, leaf, mesh, batch))
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if batch_ok:
            return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map_with_path(one, specs)
