"""Logical-axis sharding hints, decoupled from model code.

Model code calls ``hint(x, "act_btd")`` etc.; the launcher installs a rules
object mapping logical names → PartitionSpec for the active mesh.  With no
rules installed (unit tests, single device) hints are identity — model code
never imports mesh machinery.

Under ``with mesh:`` (the context used by dryrun/train), bare-PartitionSpec
``with_sharding_constraint`` resolves against the context mesh.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


class ShardingRules:
    """name → PartitionSpec table; unknown names are identity (no constraint)."""

    def __init__(self, table: dict[str, P]):
        self.table = dict(table)

    def apply(self, x, name: str):
        spec = self.table.get(name)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, spec)


def current_rules() -> ShardingRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def hint(x, name: str):
    rules = current_rules()
    return x if rules is None else rules.apply(x, name)
