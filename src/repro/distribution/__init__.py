from .hints import ShardingRules, use_rules, hint, current_rules
