"""int8 gradient all-reduce with error feedback (beyond-paper distributed-
optimization trick, DESIGN.md §4).

Wire-format compression needs the reduction implemented manually — a plain
``psum(int8)`` would still move int32 on the wire after XLA's accumulation-
type promotion.  ``ring_allreduce_int8`` is a textbook ring: N−1
reduce-scatter steps + N−1 all-gather steps via ``lax.ppermute``, moving
int8 chunks only → 4× collective-byte reduction vs f32 psum (2× vs bf16).

Quantisation: shared per-tensor scale = pmax(|g|)/127 (one scalar pmax —
negligible), stochastic-free symmetric rounding.  ``ErrorFeedback`` carries
the per-leaf quantisation residual into the next step (Karimireddy et al.
2019 — keeps SGD convergence despite biased rounding).

Used under ``shard_map`` on the DP axes; validated numerically in
tests/test_compression.py (subprocess with 8 host devices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def quantize_int8(x, scale):
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def shared_scale(x, axis_name, axis_size: int = 1):
    """Shared int8 scale covering the worst-case partial SUM (running
    accumulations grow up to axis_size × the per-shard max — scaling by N
    prevents clipping at the cost of proportionally coarser rounding, the
    inherent precision/size trade of int8 reduction)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    return jnp.maximum(amax * axis_size, 1e-12) / 127.0


def ring_allreduce_int8(x, axis_name: str, axis_size: int):
    """All-reduce ``x`` (f32) with int8 wire traffic. Mean-reduced output.

    x is padded to a multiple of axis_size and chunked; each step sends one
    int8 chunk to the next rank (ppermute ring). Local accumulation is f32
    (re-quantised before each hop — the re-quantisation error is what the
    error-feedback buffer absorbs).
    """
    if axis_size == 1:
        return x
    scale = shared_scale(x, axis_name, axis_size)
    orig_shape = x.shape
    n = x.size
    pad = (-n) % axis_size
    flat = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    chunks = flat.reshape(axis_size, -1)                    # [N, C]
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    # --- reduce-scatter: after N−1 steps, rank r owns the full sum of chunk r+1
    acc = chunks                                            # f32 accum
    send = quantize_int8(chunks, scale)                     # int8 on the wire

    def rs_step(i, carry):
        acc, send = carry
        recv = jax.lax.ppermute(send, axis_name, perm)
        # chunk index being accumulated this step at this rank:
        k = (idx - i - 1) % axis_size
        upd = acc[k] + dequantize_int8(recv[k], scale)
        acc = acc.at[k].set(upd)
        send = send.at[k].set(quantize_int8(upd, scale))
        return acc, send

    acc, send = jax.lax.fori_loop(0, axis_size - 1, rs_step, (acc, send))

    # --- all-gather: circulate the owned (fully-reduced) chunks
    own = (idx + 1) % axis_size
    out = jnp.zeros_like(chunks)
    out = out.at[own].set(acc[own])
    send_q = quantize_int8(acc, scale)

    def ag_step(i, carry):
        out, send_q = carry
        recv = jax.lax.ppermute(send_q, axis_name, perm)
        k = (idx - i) % axis_size
        out = out.at[k].set(dequantize_int8(recv[k], scale))
        send_q = send_q.at[k].set(recv[k])
        return out, send_q

    out, _ = jax.lax.fori_loop(0, axis_size - 1, ag_step, (out, send_q))
    total = out.reshape(-1)[:n].reshape(orig_shape)
    return total / axis_size


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_with_feedback(grads, ef_state, reduce_fn):
    """g' = reduce(g + e);  e ← (g + e) − dequant-path(g + e).

    ``reduce_fn(leaf)`` performs the lossy reduction (e.g. ring int8).  The
    residual uses the local quantisation error (the standard EF-SGD form).
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        reduced = reduce_fn(corrected)
        # local residual: what int8 rounding destroyed of OUR contribution
        scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        local_q = dequantize_int8(quantize_int8(corrected, scale), scale)
        new_e = corrected - local_q
        return reduced, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in out])
    new_e = jax.tree.unflatten(tree, [o[1] for o in out])
    return new_g, new_e


def fake_quantize_grads(grads):
    """Single-device numerical model of the compressed all-reduce (tests &
    single-host training): quantise→dequantise each leaf with its own scale."""
    def one(g):
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        return dequantize_int8(quantize_int8(g.astype(jnp.float32), scale), scale)
    return jax.tree.map(one, grads)
