"""int8 gradient all-reduce with error feedback (beyond-paper distributed-
optimization trick, DESIGN.md §4).

Wire-format compression needs the reduction implemented manually — a plain
``psum(int8)`` would still move int32 on the wire after XLA's accumulation-
type promotion.  ``ring_allreduce_int8`` is a textbook ring: N−1
reduce-scatter steps + N−1 all-gather steps via ``lax.ppermute``, moving
int8 chunks only → 4× collective-byte reduction vs f32 psum (2× vs bf16).

Quantisation: shared per-tensor scale = pmax(|g|)/127 (one scalar pmax —
negligible), stochastic-free symmetric rounding.  ``ErrorFeedback`` carries
the per-leaf quantisation residual into the next step (Karimireddy et al.
2019 — keeps SGD convergence despite biased rounding).

Used under ``shard_map`` on the DP axes; validated numerically in
tests/test_distribution.py (ring semantics on the 8-host-device ``mesh8``
substrate) and tests/test_engine_sharded.py (stop-iteration parity of the
``EngineConfig(stats_compression="int8_ef")`` fit path against fp32 psum).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def shared_scale(x, axis_name, axis_size: int = 1):
    """Shared int8 scale covering the worst-case partial SUM (running
    accumulations grow up to axis_size × the per-shard max — scaling by N
    prevents clipping at the cost of proportionally coarser rounding, the
    inherent precision/size trade of int8 reduction)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    return jnp.maximum(amax * axis_size, 1e-12) / 127.0


def ring_allreduce_int8(x, axis_name: str, axis_size: int, *,
                        mean: bool = True):
    """All-reduce ``x`` (f32) with int8 wire traffic.

    x is padded to a multiple of axis_size and chunked; each step sends one
    int8 chunk to the next rank (ppermute ring). Local accumulation is f32
    (re-quantised before each hop — the re-quantisation error is what the
    error-feedback buffer absorbs).  ``mean=False`` returns the SUM, matching
    ``psum`` semantics for sufficient statistics.

    The output is bit-identical on every shard: each rank's own chunk goes
    through the same quantise→dequantise round trip as the copies it ships
    to its peers.  Replicated callers (e.g. a ``while_loop`` stop decision
    under ``shard_map``) depend on this — shards disagreeing in the last
    int8 ulp would take different trip counts and deadlock the collective.
    """
    if axis_size == 1:
        return x
    scale = shared_scale(x, axis_name, axis_size)
    orig_shape = x.shape
    n = x.size
    pad = (-n) % axis_size
    flat = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    chunks = flat.reshape(axis_size, -1)                    # [N, C]
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    # --- reduce-scatter: after N−1 steps, rank r owns the full sum of chunk
    # r+1.  Each hop permutes ONE int8 [C] chunk (the partial sum computed
    # last step), not the whole buffer — wire traffic is 2·(N−1)/N × payload.
    acc = chunks                                            # f32 accum

    def rs_step(i, acc):
        s = (idx - i) % axis_size               # chunk we finished last step
        send = quantize_int8(acc[s], scale)     # int8 [C] on the wire
        recv = jax.lax.ppermute(send, axis_name, perm)
        k = (idx - i - 1) % axis_size           # chunk we accumulate now
        return acc.at[k].add(dequantize_int8(recv, scale))

    acc = jax.lax.fori_loop(0, axis_size - 1, rs_step, acc)

    # --- all-gather: circulate the owned (fully-reduced) chunk.  The owner
    # quantises once; the int8 payload is forwarded unchanged, and the owner
    # keeps the same quantise→dequantise round trip its peers see, so the
    # gathered result is bit-identical on every shard.
    own = (idx + 1) % axis_size
    own_q = quantize_int8(acc[own], scale)      # int8 [C]
    out = jnp.zeros_like(chunks)
    out = out.at[own].set(dequantize_int8(own_q, scale))

    def ag_step(i, carry):
        out, send = carry
        recv = jax.lax.ppermute(send, axis_name, perm)
        k = (idx - i) % axis_size
        out = out.at[k].set(dequantize_int8(recv, scale))
        return out, recv

    out, _ = jax.lax.fori_loop(0, axis_size - 1, ag_step, (out, own_q))
    total = out.reshape(-1)[:n].reshape(orig_shape)
    return total / axis_size if mean else total


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_with_feedback(grads, ef_state, reduce_fn, scale_fn=None):
    """g' = reduce(g + e);  e ← (g + e) − dequant-path(g + e).

    ``reduce_fn(leaf)`` performs the lossy reduction (e.g. ring int8).  The
    residual uses the quantisation error of OUR contribution (the standard
    EF-SGD form).  ``scale_fn(leaf)`` must return the scale the reduce path
    quantises with — when ``reduce_fn`` is ``ring_allreduce_int8`` that is
    ``shared_scale`` (pmax × axis_size), NOT the local ``max(|leaf|)/127``:
    with the wrong scale the residual models rounding that never happened
    and the EF buffer absorbs the wrong error.  Defaults to the local scale
    for the single-device ``fake_quantize_grads`` path, where the two
    coincide.
    """
    if scale_fn is None:
        scale_fn = lambda g: jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        reduced = reduce_fn(corrected)
        # residual: what the wire's quantisation destroyed of OUR contribution
        scale = scale_fn(corrected)
        local_q = dequantize_int8(quantize_int8(corrected, scale), scale)
        new_e = corrected - local_q
        return reduced, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in out])
    new_e = jax.tree.unflatten(tree, [o[1] for o in out])
    return new_g, new_e


def ring_wire_bytes(payload_bytes: int, axis_size: int) -> int:
    """Bytes each device SENDS for one ring all-reduce of a payload of
    ``payload_bytes``: N−1 reduce-scatter hops + N−1 all-gather hops, one
    1/N-sized chunk per hop → 2·(N−1)/N × payload.  The same factor applies
    to an fp32 ring, so it cancels in int8-vs-fp32 byte ratios — but the
    absolute numbers are what a cost model consumes."""
    if axis_size <= 1:
        return 0
    return int(2 * (axis_size - 1) * payload_bytes) // int(axis_size)


def fake_quantize_grads(grads):
    """Single-device numerical model of the compressed all-reduce (tests &
    single-host training): quantise→dequantise each leaf with its own scale."""
    def one(g):
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        return dequantize_int8(quantize_int8(g.astype(jnp.float32), scale), scale)
    return jax.tree.map(one, grads)
